"""Consistency-policy-driven parameter synchronization across pods.

This is the paper's technique as a first-class training feature.  Pods
are the replicas: parameters carry an explicit leading replica dimension
``(n_pods, ...)`` sharded over the mesh's 'pod' axis, so replica
divergence, merges, and their collective traffic are *explicit in the
HLO* (inter-pod bytes = collectives whose replica groups span pods —
billed as inter-DC traffic by the paper's cost model).

Two compiled programs per policy (MaxText-style multi-program stepping):

  * ``local``  — per-pod grad + optimizer update, zero inter-pod comm;
  * ``sync``   — local step + the policy's merge:

      ALL     mean over the pod axis every step (synchronous DP);
      QUORUM  rotating majority-subgroup mean every step;
      ONE     ring gossip with period Δ (no ordering — the violating
              baseline);
      CAUSAL  every-step vector-clock-ordered merge;
      TCC     Δ-periodic timed-causal merge (no session floors);
      X_STCC  Δ-periodic timed-causal merge + session guarantees +
              optional inter-pod compression (int8 / top-k).

The X-STCC bookkeeping goes through
``repro.core.replicated_store.ReplicatedStore`` with client i = pod i's
training process and replica i = pod i's parameter copy; every merge
registers one batched write per pod in the DUOT, advances vector clocks
through the store's batch ops and ``merge``, and (optionally) runs the
audit.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import duot as duot_lib
from repro.core import xstcc
from repro.core.consistency import ConsistencyLevel, ConsistencyPolicy
from repro.core.replicated_store import ReplicatedStore
from repro.sync import compression

Array = jax.Array


class SyncState(NamedTuple):
    cluster: xstcc.ClusterState   # P pods as both clients and replicas
    duot: duot_lib.Duot           # op log for the audit layer
    anchor: Any                   # last merged snapshot (compression) or None
    residual: Any                 # top-k error feedback or None
    merges: Array                 # () int32
    inter_pod_gb: Array           # () float32 — analytic billed traffic
    violations: Array             # () int32 — audit-detected violations
    severity: Array               # () float32 — last audit severity


class SyncEngine:
    """Per-policy merge engine over pod-stacked parameter pytrees."""

    def __init__(self, policy: ConsistencyPolicy, n_pods: int,
                 params_template=None):
        self.policy = policy
        self.n_pods = max(1, n_pods)
        p = self.n_pods
        # All session-floor / clock bookkeeping goes through the store
        # facade: pods are both the clients and the replicas, and the
        # single resource is the parameter vector.
        self._store = ReplicatedStore(
            p, p, 1, level=policy.level, merge_every=policy.delta_steps,
            delta=policy.delta_steps, pending_cap=max(4 * p, 16),
            duot_cap=policy.duot_capacity,
        )
        self._wire_gb = None
        if params_template is not None:
            self._wire_gb = self.merge_wire_bytes(
                self.payload_bytes(params_template)) / 1e9

    # -- static accounting ---------------------------------------------------

    def payload_bytes(self, params_template) -> float:
        """One pod's merge payload in bytes (analytic, for the bill)."""
        inner = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                             params_template)
        method = (self.policy.compress_inter_pod
                  if self.policy.level is ConsistencyLevel.X_STCC else "none")
        return compression.wire_bytes(inner, method, self.policy.topk_fraction)

    def merge_wire_bytes(self, payload: float) -> float:
        """Total inter-pod wire bytes of ONE merge, by collective shape.

        ALL/CAUSAL/TCC/X-STCC(mean): ring all-reduce  = 2(P-1) x payload
        QUORUM: all-reduce within the quorum          = 2(q-1) x payload
        ONE: neighbor gossip (one hop per pod)        =      P x payload
        X-STCC compressed: quantized ring reduce      = 2(P-1) x payload'
        (payload' already reflects the compression.)"""
        p = self.n_pods
        lv = self.policy.level
        if p <= 1:
            return 0.0
        if lv is ConsistencyLevel.ONE:
            return p * payload
        if lv is ConsistencyLevel.QUORUM:
            q = self.policy.quorum_size(p)
            return 2 * max(q - 1, 1) * payload
        return 2 * (p - 1) * payload

    # -- state ---------------------------------------------------------------

    def init_state(self, params_stacked) -> SyncState:
        p = self.n_pods
        needs_anchor = (
            self.policy.level is ConsistencyLevel.X_STCC
            and self.policy.compress_inter_pod != "none"
        )
        anchor = (
            jax.tree.map(lambda x: x[0], params_stacked) if needs_anchor else None
        )
        residual = (
            jax.tree.map(jnp.zeros_like, params_stacked)
            if self.policy.compress_inter_pod == "topk"
            else None
        )
        store0 = self._store.init()
        return SyncState(
            cluster=store0.cluster,
            duot=store0.duot,
            anchor=anchor,
            residual=residual,
            merges=jnp.zeros((), jnp.int32),
            inter_pod_gb=jnp.zeros((), jnp.float32),
            violations=jnp.zeros((), jnp.int32),
            severity=jnp.zeros((), jnp.float32),
        )

    # -- merges --------------------------------------------------------------

    def merge(
        self, params, sync: SyncState, up: Array | None = None
    ) -> tuple[Any, SyncState]:
        """Apply the policy's inter-pod merge to pod-stacked ``params``.

        ``up`` (``(P,)`` bool, ``None`` = all) masks the merge: pods
        outside the mask drop out — they neither contribute to nor
        receive this merge's combined parameters, and the protocol
        bookkeeping propagates only among the live pods (the same
        availability mask the replicated store's failure path uses,
        replacing the old ad-hoc straggler weight vector).  A dropped
        pod keeps its local parameters and catches up at the next merge
        it participates in — the Δ bound caps how stale it can get.
        """
        if self.n_pods == 1:
            return params, sync._replace(merges=sync.merges + 1)
        level = self.policy.level
        if level in (ConsistencyLevel.ALL, ConsistencyLevel.TWO):
            new = self._mean_merge(params, up)
        elif level is ConsistencyLevel.QUORUM:
            new = self._quorum_merge(params, sync.merges, up)
        elif level is ConsistencyLevel.ONE:
            new = self._gossip_merge(params, up)
        elif level is ConsistencyLevel.CAUSAL:
            new = self._mean_merge(params, up)
        else:  # TCC / X_STCC
            new, sync = self._xstcc_merge(params, sync, up)
        sync = self._bookkeep(sync, level, up)
        return new, sync

    def _pod_weights(self, up: Array | None):
        """(per-pod f32 weights, live count) for masked reductions."""
        if up is None:
            return None, float(self.n_pods)
        w = jnp.asarray(up, bool).astype(jnp.float32)
        return w, jnp.maximum(jnp.sum(w), 1.0)

    def _mean_merge(self, params, up: Array | None = None):
        w, n = self._pod_weights(up)

        def m(x):
            x32 = x.astype(jnp.float32)
            if w is None:
                mean = jnp.mean(x32, axis=0, keepdims=True)
                return jnp.broadcast_to(mean, x.shape).astype(x.dtype)
            wb = w.reshape((self.n_pods,) + (1,) * (x.ndim - 1))
            mean = jnp.sum(x32 * wb, axis=0, keepdims=True) / n
            return jnp.where(
                wb > 0, jnp.broadcast_to(mean, x.shape), x32
            ).astype(x.dtype)

        return jax.tree.map(m, params)

    def _quorum_merge(self, params, merges, up: Array | None = None):
        p = self.n_pods
        q = self.policy.quorum_size(p)
        start = jnp.mod(merges, p)
        idx = jnp.arange(p, dtype=jnp.int32)
        member = jnp.mod(idx - start, p) < q  # rotating quorum membership
        if up is not None:
            member = member & jnp.asarray(up, bool)
            denom = jnp.maximum(jnp.sum(member.astype(jnp.float32)), 1.0)
        else:
            denom = q

        def m(x):
            mask = member.reshape((p,) + (1,) * (x.ndim - 1))
            x32 = x.astype(jnp.float32)
            msum = jnp.sum(jnp.where(mask, x32, 0.0), axis=0, keepdims=True)
            merged = msum / denom
            return jnp.where(mask, merged, x32).astype(x.dtype)

        return jax.tree.map(m, params)

    def _gossip_merge(self, params, up: Array | None = None):
        # A gossip hop runs only when both endpoints are live.
        ok = None
        if up is not None:
            u = jnp.asarray(up, bool)
            ok = u & jnp.roll(u, 1)

        def m(x):
            x32 = x.astype(jnp.float32)
            mixed = (x32 + jnp.roll(x32, 1, axis=0)) * 0.5
            if ok is None:
                return mixed.astype(x.dtype)
            okb = ok.reshape((self.n_pods,) + (1,) * (x.ndim - 1))
            return jnp.where(okb, mixed, x32).astype(x.dtype)

        return jax.tree.map(m, params)

    def _xstcc_merge(self, params, sync: SyncState, up: Array | None = None):
        method = self.policy.compress_inter_pod
        if method == "none":
            return self._mean_merge(params, up), sync

        anchor = sync.anchor
        p = self.n_pods
        w, n_live = self._pod_weights(up)

        if method == "int8":
            def m(x, a):
                delta = x.astype(jnp.float32) - a.astype(jnp.float32)[None]
                red = tuple(range(1, x.ndim))
                scale = jnp.maximum(
                    jnp.max(jnp.abs(delta), axis=red), 1e-12) / 127.0
                q = jnp.clip(
                    jnp.round(delta / scale.reshape((p,) + (1,) * (x.ndim - 1))),
                    -127, 127).astype(jnp.int8)
                # int8 on the wire: the stacked int8 tensor is replicated
                # (all-gather of s8) and combined locally.
                deq = q.astype(jnp.float32) * scale.reshape(
                    (p,) + (1,) * (x.ndim - 1))
                if w is None:
                    mean_delta = jnp.mean(deq, axis=0)
                    merged = a.astype(jnp.float32) + mean_delta
                    out = jnp.broadcast_to(merged[None], x.shape)
                else:
                    wb = w.reshape((p,) + (1,) * (x.ndim - 1))
                    mean_delta = jnp.sum(deq * wb, axis=0) / n_live
                    merged = a.astype(jnp.float32) + mean_delta
                    out = jnp.where(
                        wb > 0,
                        jnp.broadcast_to(merged[None], x.shape),
                        x.astype(jnp.float32),
                    )
                return out.astype(x.dtype), merged.astype(a.dtype)

            pairs = jax.tree.map(m, params, anchor)
            new = jax.tree.map(lambda t: t[0], pairs,
                               is_leaf=lambda t: isinstance(t, tuple))
            new_anchor = jax.tree.map(lambda t: t[1], pairs,
                                      is_leaf=lambda t: isinstance(t, tuple))
            return new, sync._replace(anchor=new_anchor)

        # top-k with error feedback
        frac = self.policy.topk_fraction

        def m(x, a, r):
            delta = (x.astype(jnp.float32) - a.astype(jnp.float32)[None]
                     + r.astype(jnp.float32))
            flat = delta.reshape(p, -1)
            k = max(1, int(flat.shape[1] * frac))
            mag = jnp.abs(flat)
            _, idx = jax.lax.top_k(mag, k)                      # (p, k)
            vals = jnp.take_along_axis(flat, idx, axis=1)
            sparse = jnp.zeros_like(flat).at[
                jnp.arange(p)[:, None], idx].set(vals)
            if w is None:
                new_resid = (flat - sparse).reshape(x.shape).astype(x.dtype)
                mean_delta = jnp.mean(sparse, axis=0).reshape(x.shape[1:])
                merged = a.astype(jnp.float32) + mean_delta
                out = jnp.broadcast_to(merged[None], x.shape)
            else:
                # A dropped pod transmits nothing: its sparse update is
                # excluded, its residual untouched, its params kept.
                wf = w[:, None]
                new_resid = jnp.where(
                    wf > 0, flat - sparse, r.astype(jnp.float32).reshape(p, -1)
                ).reshape(x.shape).astype(x.dtype)
                mean_delta = (
                    jnp.sum(sparse * wf, axis=0) / n_live
                ).reshape(x.shape[1:])
                merged = a.astype(jnp.float32) + mean_delta
                wb = w.reshape((p,) + (1,) * (x.ndim - 1))
                out = jnp.where(
                    wb > 0,
                    jnp.broadcast_to(merged[None], x.shape),
                    x.astype(jnp.float32),
                )
            return (out.astype(x.dtype), merged.astype(a.dtype), new_resid)

        triples = jax.tree.map(m, params, anchor, sync.residual)
        is3 = lambda t: isinstance(t, tuple) and len(t) == 3
        new = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
        new_anchor = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
        new_resid = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
        return new, sync._replace(anchor=new_anchor, residual=new_resid)

    # -- protocol bookkeeping --------------------------------------------------

    def _bookkeep(
        self, sync: SyncState, level: ConsistencyLevel,
        up: Array | None = None,
    ) -> SyncState:
        """Register this merge in the protocol state.

        Data-plane mirror of the merge: each pod *writes* its update at
        its home replica; each pod then *reads* at its neighbor replica
        (the paper's Fig. 2 mobility scenario — Bob reconnecting to a
        different server); finally the server-side propagation runs.

        Synchronous levels (ALL/TWO/QUORUM) propagate before the reads
        (write-acks span the replica set); causal-family levels
        propagate after, bounded by Δ — so ONE and plain CAUSAL expose
        session violations at the neighbor read, while X-STCC's
        enforcement repairs them (and counts zero).

        ``up`` masks the propagation to the pods in this merge: a
        dropped pod still commits its local write (it keeps training),
        but the server-side merge only moves versions among live pods,
        so its replica goes observably stale until it rejoins."""
        p = self.n_pods
        store = self._store
        st = store.wrap(sync.cluster, sync.duot)
        idx = jnp.arange(p, dtype=jnp.int32)
        res0 = jnp.zeros((p,), jnp.int32)

        # One batched write per pod at its home replica.
        st, _ = store.write_batch(st, client=idx, replica=idx, resource=res0)

        sync_ack = level in (
            ConsistencyLevel.ALL, ConsistencyLevel.TWO, ConsistencyLevel.QUORUM
        )
        if sync_ack:
            # Write acks span the replica set before the write commits.
            st, _ = store.merge(st, delta=0, up=up)

        # Batched read at the *neighbor* replica (client mobility).
        # X-STCC enforces the session floors (store.enforce_sessions);
        # weaker levels serve raw replicas.
        st, reads = store.read_batch(
            st, client=idx, replica=jnp.mod(idx + 1, p), resource=res0
        )
        viol = sync.violations + jnp.sum(reads.violation.astype(jnp.int32))

        if not sync_ack:
            # Timed-causal propagation (bounded by Δ for TCC/X-STCC).
            st, _ = store.merge(st, delta=self.policy.delta_steps, up=up)

        severity = sync.severity
        if self.policy.audit_every and level.is_causal:
            res = store.audit(st, delta=self.policy.delta_steps * p)
            severity = res.severity
            # GC entries covered at every replica.
            st = store.gc(st)

        gb = jnp.float32(0.0 if self._wire_gb is None else self._wire_gb)
        return sync._replace(
            cluster=st.cluster,
            duot=st.duot,
            merges=sync.merges + 1,
            inter_pod_gb=sync.inter_pod_gb + gb,
            violations=viol,
            severity=severity,
        )
