"""Inter-pod gradient/delta compression (beyond-paper optimization).

The paper's monetary-cost model bills inter-DC (= inter-pod) traffic at
$0.01/GB while intra-DC is free (Table 2).  X-STCC already divides
inter-pod traffic by Δ; compression multiplies the saving:

  * ``int8``  — per-leaf symmetric quantization.  The pod-stacked int8
    tensor is all-gathered (1 B/elem on the wire instead of a 2-4 B/elem
    all-reduce) and dequantized + averaged locally.
  * ``topk``  — magnitude top-k sparsification: (values, indices) pairs,
    k = ``fraction`` x size; wire bytes ~ 5-6 B x k instead of 2-4 B x n.

Both are *merge-compatible*: compress(delta_i) per pod, exchange, then
average — the deterministic X-STCC merge order is preserved because the
combine (mean) is commutative and the session version counter, not the
payload, orders the merge.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def int8_quantize(x: Array) -> tuple[Array, Array]:
    """Symmetric per-leaf int8.  Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_compress_tree(tree) -> Any:
    """Pytree -> {leaf path: (q, scale)} mirrored pytree."""
    return jax.tree.map(lambda x: int8_quantize(x), tree,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def int8_decompress_tree(ctree, like) -> Any:
    return jax.tree.map(
        lambda qs, x: int8_dequantize(qs[0], qs[1], x.dtype),
        ctree,
        like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


def topk_sparsify(x: Array, fraction: float) -> tuple[Array, Array, Array]:
    """Keep the top-|fraction| entries by magnitude.

    Returns (values (k,), indices (k,) int32, error_feedback residual)."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.shape[0] * fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(x.shape)
    return kept, idx.astype(jnp.int32), residual.astype(x.dtype)


def topk_densify(values: Array, indices: Array, shape, dtype) -> Array:
    n = 1
    for s in shape:
        n *= s
    out = jnp.zeros((n,), jnp.float32).at[indices].add(values)
    return out.reshape(shape).astype(dtype)


def wire_bytes(tree, method: str, fraction: float = 0.01) -> int:
    """Analytic wire size of one pod's payload (for the cost model)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(leaf.size)
        if method == "none":
            total += n * leaf.dtype.itemsize
        elif method == "int8":
            total += n * 1 + 4
        elif method == "topk":
            k = max(1, int(n * fraction))
            total += k * (4 + 4)
        else:
            raise ValueError(method)
    return total
