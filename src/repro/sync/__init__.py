from repro.sync.engine import SyncEngine, SyncState
from repro.sync import compression

__all__ = ["SyncEngine", "SyncState", "compression"]
