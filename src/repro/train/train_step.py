"""Training step construction: per-pod local steps + policy merges.

``make_train_fns`` returns two step functions over pod-stacked state
(leaves carry a leading ``(n_pods, ...)`` replica dim, sharded over the
mesh's 'pod' axis):

  * ``local_step``  — vmapped per-pod grad + AdamW; zero inter-pod comm.
  * ``sync_step``   — local step followed by the consistency merge.

The trainer alternates them according to the policy period (the compiled
HLO of each is what the dry-run and the cost model account separately).

Optimizer moments deliberately stay pod-local between merges (the
DiLoCo-style choice): the paper's protocol replicates the *data* (here:
parameters), not the optimizer's private scratch state.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.consistency import ConsistencyPolicy
from repro.models.model_zoo import Model
from repro.optim import adamw
from repro.sync.engine import SyncEngine, SyncState

Array = jax.Array


class TrainState(NamedTuple):
    params: Any       # pod-stacked pytree
    opt: adamw.AdamWState
    sync: SyncState
    step: Array       # () int32


class TrainFns(NamedTuple):
    init: Any
    local_step: Any
    sync_step: Any
    engine: SyncEngine


def stack_for_pods(tree, n_pods: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), tree
    )


def make_train_fns(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    policy: ConsistencyPolicy,
    n_pods: int,
) -> TrainFns:
    n_pods = max(1, n_pods)
    params_template = jax.eval_shape(model.init, jax.random.key(0))
    stacked_template = jax.eval_shape(
        lambda: stack_for_pods(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_template),
            n_pods,
        )
    )
    engine = SyncEngine(policy, n_pods, params_template=stacked_template)

    def init(key) -> TrainState:
        params = model.init(key)
        stacked = stack_for_pods(params, n_pods)
        opt = adamw.init(stacked, opt_cfg)
        return TrainState(
            params=stacked,
            opt=opt,
            sync=engine.init_state(stacked),
            step=jnp.zeros((), jnp.int32),
        )

    def one_pod(params, mu, nu, count, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        opt_state = adamw.AdamWState(mu=mu, nu=nu, count=count)
        new_params, new_opt, om = adamw.apply(params, grads, opt_state, opt_cfg)
        return new_params, new_opt.mu, new_opt.nu, new_opt.count, loss, om

    # spmd_axis_name binds the replica dim to the mesh's 'pod' axis so
    # inner shard_maps/constraints (MoE dispatch, ring attention) stay
    # consistent under the vmap — without it the XLA partitioner crashes
    # on mixed auto/manual specs (observed on the multi-pod MoE cells).
    vpod = (jax.vmap(one_pod, spmd_axis_name="pod") if n_pods > 1
            else jax.vmap(one_pod))

    def local_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        from repro.models import sharding as shlib

        shlib.set_pod_vmap(n_pods > 1)  # trace-time flag (see moe.py)
        count = jnp.broadcast_to(state.opt.count, (n_pods,))
        new_params, mu, nu, counts, loss, om = vpod(
            state.params, state.opt.mu, state.opt.nu, count, batch
        )
        new_state = TrainState(
            params=new_params,
            opt=adamw.AdamWState(mu=mu, nu=nu, count=counts[0]),
            sync=state.sync,
            step=state.step + 1,
        )
        metrics = {
            "loss": jnp.mean(loss),
            "grad_norm": jnp.mean(om["grad_norm"]),
            "lr": om["lr"][0],
        }
        return new_state, metrics

    def sync_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        state, metrics = local_step(state, batch)
        new_params, new_sync = engine.merge(state.params, state.sync)
        state = state._replace(params=new_params, sync=new_sync)
        metrics = dict(
            metrics,
            merges=new_sync.merges,
            inter_pod_gb=new_sync.inter_pod_gb,
            violations=new_sync.violations,
            severity=new_sync.severity,
        )
        return state, metrics

    return TrainFns(init=init, local_step=local_step, sync_step=sync_step,
                    engine=engine)


def split_batch_for_pods(batch: dict, n_pods: int) -> dict:
    """(B, ...) -> (n_pods, B/n_pods, ...)."""
    def sp(x):
        b = x.shape[0]
        assert b % n_pods == 0, f"batch {b} not divisible by {n_pods} pods"
        return x.reshape((n_pods, b // n_pods) + x.shape[1:])

    return {k: sp(v) if hasattr(v, "shape") and getattr(v, "ndim", 0) > 0 else v
            for k, v in batch.items()}
