"""Training loop: policy-dispatched stepping, checkpointing, recovery.

The trainer owns the two compiled programs (local / sync) and dispatches
by the policy period; everything stateful (params, optimizer, protocol
bookkeeping) lives in the :class:`TrainState` pytree, so failure
recovery = restore state + replay the deterministic data stream from the
restored step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.consistency import ConsistencyPolicy
from repro.data import DataConfig, batch_at, extra_inputs
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.train_step import (
    TrainFns,
    TrainState,
    make_train_fns,
    split_batch_for_pods,
)


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    n_pods: int = 1
    log_every: int = 10
    ckpt_every: int = 0            # 0 = no checkpointing
    seed: int = 0
    jit: bool = True


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        data_cfg: DataConfig,
        opt_cfg: AdamWConfig,
        policy: ConsistencyPolicy,
        tcfg: TrainerConfig,
        ckpt_store=None,
        ckpt_session=None,
        health=None,
    ):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.policy = policy
        self.tcfg = tcfg
        self.model = build_model(model_cfg)
        self.fns: TrainFns = make_train_fns(
            self.model, opt_cfg, policy, tcfg.n_pods
        )
        self.ckpt_store = ckpt_store
        self.ckpt_session = ckpt_session
        self.health = health
        if tcfg.jit:
            self._local = jax.jit(self.fns.local_step, donate_argnums=(0,))
            self._sync = jax.jit(self.fns.sync_step, donate_argnums=(0,))
        else:
            self._local = self.fns.local_step
            self._sync = self.fns.sync_step
        self.history: list[dict] = []

    # -- data ------------------------------------------------------------------

    def batch_for(self, step: int) -> dict:
        batch = batch_at(self.data_cfg, step)
        batch.update(
            extra_inputs(self.model_cfg, self.data_cfg.global_batch, step)
        )
        return split_batch_for_pods(batch, self.tcfg.n_pods)

    # -- loop ------------------------------------------------------------------

    def init_state(self) -> TrainState:
        return self.fns.init(jax.random.key(self.tcfg.seed))

    def is_sync_step(self, step: int) -> bool:
        return (step + 1) % self.fns.engine.policy.inter_pod_period() == 0

    def run(self, state: TrainState | None = None, start_step: int = 0):
        state = self.init_state() if state is None else state
        period = self.policy.inter_pod_period()
        for step in range(start_step, self.tcfg.n_steps):
            batch = self.batch_for(step)
            fn = self._sync if self.is_sync_step(step) else self._local
            t0 = time.perf_counter()
            state, metrics = fn(state, batch)
            dt = time.perf_counter() - t0
            if (step % max(1, self.tcfg.log_every)) == 0 or step == self.tcfg.n_steps - 1:
                rec = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "sec": dt,
                    "synced": self.is_sync_step(step),
                }
                if "inter_pod_gb" in metrics:
                    rec["inter_pod_gb"] = float(metrics["inter_pod_gb"])
                    rec["violations"] = int(metrics["violations"])
                    rec["severity"] = float(metrics["severity"])
                self.history.append(rec)
            if (
                self.ckpt_store is not None
                and self.tcfg.ckpt_every
                and (step + 1) % self.tcfg.ckpt_every == 0
            ):
                self.save_checkpoint(state, step + 1)
        return state

    # -- checkpoint / recovery ---------------------------------------------------

    def save_checkpoint(self, state: TrainState, step: int) -> int:
        merged = jax.tree.map(lambda x: x[0], state.params)
        return self.ckpt_store.save(merged, step, self.ckpt_session)

    def restore_checkpoint(self) -> tuple[TrainState, int]:
        from repro.train.train_step import stack_for_pods
        from repro.optim import adamw

        template = jax.eval_shape(self.model.init, jax.random.key(0))
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), template)
        params, version, _ = self.ckpt_store.restore(zeros, self.ckpt_session)
        meta_step = 0
        for r in range(self.ckpt_store.n_replicas):
            e = self.ckpt_store._read_meta(r)["entries"].get(str(version))
            if e:
                meta_step = e["step"]
                break
        stacked = stack_for_pods(params, self.tcfg.n_pods)
        opt = adamw.init(stacked, self.opt_cfg)
        opt = opt._replace(count=jnp.asarray(meta_step, jnp.int32))
        state = TrainState(
            params=stacked,
            opt=opt,
            sync=self.fns.engine.init_state(stacked),
            step=jnp.asarray(meta_step, jnp.int32),
        )
        return state, meta_step
