from repro.train.train_step import (
    TrainFns,
    TrainState,
    make_train_fns,
    split_batch_for_pods,
    stack_for_pods,
)
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "TrainFns",
    "TrainState",
    "Trainer",
    "TrainerConfig",
    "make_train_fns",
    "split_batch_for_pods",
    "stack_for_pods",
]
