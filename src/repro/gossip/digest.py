"""Per-resource-range version digests (the gossip exchange unit).

A replica's protocol state, as far as convergence is concerned, is its
``(R,)`` applied-version row of ``ClusterState.replica_version``.  The
digest layer summarizes that row over ``K`` contiguous resource ranges
into four int32 components per range — a Merkle-style leaf level, flat
because the fleet diffs *ranges*, not paths:

  * ``SUM`` — wrapping sum of applied versions in the range (the
    cumsum-of-versions summary: any missed delivery shifts it);
  * ``MAX`` — the range's version frontier (orders who is behind);
  * ``CHK`` — position-weighted wrapping checksum (odd multiplicative
    weights per resource), which catches permuted/divergent histories
    whose plain SUM collides;
  * ``CNT`` — resources ever written, separating "empty" from "stale".

Two replicas exchange ``(K, 4)`` digests (``K · DIGEST_BYTES`` bytes on
the wire, billed by the gossip drivers) and diff them with
``repro.kernels.ops.digest_compare``; ranges whose digests agree are
provably identical-in-summary and skipped, the rest get the targeted
range-restricted repair merge (``ReplicatedStore.gossip_round``).

Everything here is integer-only and shape-static, so digests jit and
the compare paths (Pallas / tiled / dense) stay bit-exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# Component order of a digest row (matches kernels.digest_compare).
SUM, MAX, CHK, CNT = 0, 1, 2, 3
N_COMPONENTS = 4
# Wire size of one range digest: four int32 components.
DIGEST_BYTES = 4 * N_COMPONENTS

# Knuth's multiplicative-hash constant; masked to 15 bits and forced
# odd so weights stay small, distinct-ish, and never zero.
_WEIGHT_MULT = 2654435761
_WEIGHT_MASK = (1 << 15) - 1


def range_of_resource(n_resources: int, n_ranges: int) -> Array:
    """(R,) int32 — the digest range covering each resource.

    Ranges are contiguous, ``ceil(R / K)`` resources each; the last
    range may be short.  ``n_ranges`` is clamped to ``[1, R]``."""
    k = max(1, min(int(n_ranges), n_resources))
    span = -(-n_resources // k)          # ceil
    rid = jnp.arange(n_resources, dtype=jnp.int32) // span
    return jnp.minimum(rid, k - 1)


def checksum_weights(n_resources: int) -> Array:
    """(R,) int32 — odd per-resource weights for the CHK component."""
    r = jnp.arange(n_resources, dtype=jnp.uint32)
    w = (r * jnp.uint32(_WEIGHT_MULT)) & jnp.uint32(_WEIGHT_MASK)
    return (w | jnp.uint32(1)).astype(jnp.int32)


def range_digests(replica_version: Array, n_ranges: int) -> Array:
    """Digest every replica's version row; ``(P, K, 4)`` int32.

    ``replica_version`` is the ``(P, R)`` applied-version table (a
    single ``(R,)`` row also works and yields ``(K, 4)``).  Wrapping
    int32 arithmetic throughout — overflow is deliberate (the digest is
    a checksum, not a measure)."""
    v = jnp.asarray(replica_version, jnp.int32)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[None]
    p, r = v.shape
    k = max(1, min(int(n_ranges), r))
    rid = range_of_resource(r, k)
    w = checksum_weights(r)
    z = jnp.zeros((p, k), jnp.int32)
    out = jnp.stack(
        [
            z.at[:, rid].add(v),
            z.at[:, rid].max(v),
            z.at[:, rid].add(v * w[None, :]),
            z.at[:, rid].add((v > 0).astype(jnp.int32)),
        ],
        axis=-1,
    )
    return out[0] if squeeze else out
