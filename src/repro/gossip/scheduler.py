"""Gossip cadence configuration and peer-pair schedules.

The digest-exchange pass is *scheduled*, not reactive: every
``cadence`` merge epochs each replica contacts one peer, diffs range
digests, and repairs the stale ranges (see
``ReplicatedStore.gossip_round``).  This module owns the two host-side
ingredients the jitted drivers consume as plain scan inputs:

  * :class:`GossipConfig` — the frozen, hashable knob bundle (cadence
    in merge epochs, digest range count, peer-selection policy, hint
    queue bound, compare-kernel impl).  Hashable on purpose: it keys
    the ``lru_cache``'d runners in ``repro.storage.simulator`` exactly
    like the consistency level does.  ``cadence=0`` disables gossip
    outright — the drivers then build the byte-identical heal-only
    trace (no gossip inputs, no extra carry), which is what the CI
    bit-identity gate checks.
  * :func:`gossip_pairs` — the precomputed ``(T, P, 2)`` peer-pair
    schedule plus the ``(T,)`` active mask, like the availability masks
    of ``FaultSchedule``: closed-form over the epoch index, never
    derived inside the trace.

Peer selection:

  * ``"round_robin"`` — exchange ``n`` pairs replica ``p`` with
    ``(p + 1 + (n-1) mod (P-1)) mod P``: every ordered pair recurs
    every ``P-1`` exchanges, so the fleet's exchange graph cycles
    through all neighbors;
  * ``"nearest"`` — peers ordered by RTT ascending (ties by replica
    id) over a ``repro.geo.topology.RegionTopology``: cheap LAN peers
    first, the WAN peers on the long cycle — Okapi-style
    locality-aware stabilization.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Knobs of the continuous anti-entropy pass (hashable, static).

    ``cadence`` — merge epochs between digest exchanges; ``0`` disables
    gossip entirely (the bit-identity baseline).  ``n_ranges`` — digest
    ranges per replica (the repair granularity).  ``peer`` —
    ``"round_robin"`` or ``"nearest"`` (needs a topology).
    ``hint_cap`` — hinted-handoff queue bound per destination replica;
    ``0`` disables handoff.  ``impl`` — ``repro.kernels.ops.
    digest_compare`` implementation override (``None`` = auto).
    """

    cadence: int = 0
    n_ranges: int = 8
    peer: str = "round_robin"
    hint_cap: int = 0
    impl: str | None = None

    def __post_init__(self):
        if self.cadence < 0 or self.n_ranges < 1 or self.hint_cap < 0:
            raise ValueError(
                f"invalid gossip config: cadence={self.cadence}, "
                f"n_ranges={self.n_ranges}, hint_cap={self.hint_cap}"
            )
        if self.peer not in ("round_robin", "nearest"):
            raise ValueError(f"unknown peer policy: {self.peer!r}")

    @property
    def enabled(self) -> bool:
        return self.cadence > 0

    @property
    def handoff(self) -> bool:
        return self.hint_cap > 0


def _peer_order(n_replicas: int, topology) -> np.ndarray:
    """(P, P-1) int32 — each replica's peers in exchange order."""
    p = n_replicas
    if topology is None:
        # Ring offsets 1..P-1: the round-robin cycle.
        return np.stack(
            [(np.arange(1, p) + i) % p for i in range(p)]
        ).astype(np.int32)
    reg = np.asarray(topology.regions())
    rtt_g = np.asarray(topology.rtt(), np.float64)
    rtt = rtt_g[reg[:, None], reg[None, :]]     # replica-pair RTT
    order = []
    for i in range(p):
        others = np.array([j for j in range(p) if j != i])
        key = np.lexsort((others, rtt[i, others]))
        order.append(others[key])
    return np.stack(order).astype(np.int32)


def gossip_pairs(
    n_replicas: int,
    n_epochs: int,
    cfg: GossipConfig,
    topology=None,
) -> tuple[np.ndarray, np.ndarray]:
    """(active, pairs) — the schedule's per-epoch exchange plan.

    ``active`` is ``(T,)`` bool (epoch ends with a digest exchange —
    every ``cadence``-th epoch); ``pairs`` is ``(T, P, 2)`` int32, row
    ``p`` of epoch ``t`` being the ordered ``(p, peer)`` exchange.  On
    inactive epochs pairs are self-loops ``(p, p)`` — the repair merge
    treats them as invalid, so the arrays stay shape-static.
    ``peer="nearest"`` requires ``topology`` (its region RTT matrix
    orders the peers); round-robin ignores it.
    """
    p = n_replicas
    t = n_epochs
    active = np.zeros(t, bool)
    me = np.arange(p, dtype=np.int32)
    pairs = np.stack([me, me], axis=1)[None].repeat(t, axis=0)
    if not cfg.enabled or p < 2:
        return active, pairs.astype(np.int32)
    if cfg.peer == "nearest" and topology is None:
        raise ValueError('peer="nearest" needs a RegionTopology')
    order = _peer_order(p, topology if cfg.peer == "nearest" else None)
    epochs = np.arange(t)
    active = (epochs + 1) % cfg.cadence == 0
    nth = (epochs + 1) // cfg.cadence - 1      # 0-based exchange counter
    col = nth % (p - 1)
    for ti in np.flatnonzero(active):
        pairs[ti, :, 1] = order[:, col[ti]]
    return active, pairs.astype(np.int32)
