"""Continuous gossip anti-entropy + hinted handoff.

Between failures the replicated fleet only reconciled at heal time
(PR 4); this subsystem makes convergence *proactive*, the way the
paper's eq. 8 network-cost term trades against its staleness metrics:

  * :mod:`repro.gossip.digest` — per-resource-range version summaries
    (wrapping SUM / MAX / weighted CHK / nonzero CNT over the store's
    ``replica_version`` table), compact enough that a digest exchange
    ships ``K · DIGEST_BYTES`` instead of full state;
  * ``repro.kernels.digest_compare`` — the tiled Pallas kernel (plus
    bit-exact jnp twin and dense oracle behind
    ``repro.kernels.ops.digest_compare``) that diffs two replicas'
    digests and emits the stale-range mask;
  * :mod:`repro.gossip.scheduler` — :class:`GossipConfig` (cadence in
    merge epochs, peer selection, hint-queue bounds) and the host-side
    peer-pair schedules (round-robin, or nearest-by-RTT over a
    ``repro.geo.topology.RegionTopology``);
  * hinted handoff — bounded per-replica hint queues on
    ``repro.core.replicated_store.ReplicatedStore`` (``enqueue_hints``
    / ``drain_hints``) that front-run the heal-time anti-entropy pass
    with targeted deliveries, overflow falling back to digest repair.

The data-plane integration lives in
``repro.storage.simulator.run_protocol_faulty`` /
``run_protocol_geo`` (per-round repair telemetry, eq. 8 + egress-matrix
billing) and the cadence policy knob in
``repro.policy.controller.CadenceController``.  With gossip disabled
(``GossipConfig(cadence=0)`` or no config at all) every run is
bit-identical to the heal-only path — gated by
``benchmarks/bench_gossip.py --check``.
"""

from repro.gossip.digest import (
    DIGEST_BYTES,
    N_COMPONENTS,
    checksum_weights,
    range_digests,
    range_of_resource,
)
from repro.gossip.scheduler import GossipConfig, gossip_pairs

__all__ = [
    "DIGEST_BYTES",
    "N_COMPONENTS",
    "GossipConfig",
    "checksum_weights",
    "gossip_pairs",
    "range_digests",
    "range_of_resource",
]
