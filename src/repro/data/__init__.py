from repro.data.synthetic import DataConfig, batch_at, extra_inputs

__all__ = ["DataConfig", "batch_at", "extra_inputs"]
