"""Deterministic synthetic token pipeline.

Produces reproducible LM batches from a counter-based PRNG (stateless:
``batch_at(step)``), so every pod/worker derives identical data order
without coordination — restart-safe by construction (the fault-tolerance
path replays from the step counter alone).

A Zipf-ish unigram marginal plus a short-range bigram correlation makes
the loss curve non-trivial (pure uniform tokens give a constant-entropy
floor from step 0), which the convergence tests rely on.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    copy_prob: float = 0.3   # probability a token repeats k-back (structure)
    copy_back: int = 4


def _zipf_logits(cfg: DataConfig) -> Array:
    ranks = jnp.arange(1, cfg.vocab_size + 1, dtype=jnp.float32)
    return -cfg.zipf_alpha * jnp.log(ranks)


def batch_at(cfg: DataConfig, step: int | Array) -> dict[str, Array]:
    """The (deterministic) batch for a given step."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    logits = _zipf_logits(cfg)
    base = jax.random.categorical(
        k1, logits, shape=(cfg.global_batch, cfg.seq_len)
    ).astype(jnp.int32)
    # Inject copy structure: with prob copy_prob, token t = token t-k.
    copy_mask = (
        jax.random.uniform(k2, (cfg.global_batch, cfg.seq_len))
        < cfg.copy_prob
    )
    shifted = jnp.roll(base, cfg.copy_back, axis=1)
    tokens = jnp.where(copy_mask, shifted, base)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((cfg.global_batch, 1), -100, jnp.int32)],
        axis=1,
    )
    return {"tokens": tokens, "labels": labels}


def extra_inputs(model_cfg, global_batch: int, step: int, dtype=None) -> dict:
    """Stub modality inputs (vis_embeds / frames) for vlm/audio archs."""
    out = {}
    key = jax.random.fold_in(jax.random.key(777), step)
    dt = jnp.dtype(dtype or model_cfg.dtype)
    if model_cfg.family == "vlm":
        out["vis_embeds"] = jax.random.normal(
            key, (global_batch, model_cfg.n_vis_tokens, model_cfg.d_model),
            jnp.float32,
        ).astype(dt)
    if model_cfg.is_encdec:
        out["frames"] = jax.random.normal(
            key, (global_batch, model_cfg.n_frames, model_cfg.d_model),
            jnp.float32,
        ).astype(dt)
    return out
