"""Host-side span tracing of the engine lifecycle.

The device-resident plane (``repro.obs.metrics``) covers *what the
protocol did*; this module covers *what the host did to run it*: how
long stream preparation, XLA compilation, device execution, and result
assembly took, under which static engine configuration, with how many
jit re-entries.  A :class:`Tracer` collects spans and instant events
with microsecond wall-clock timestamps and exports them as Chrome
trace-event JSON (load ``chrome://tracing`` / Perfetto) or as JSONL
(one event per line, grep/jq-friendly).

:func:`traced_run` is the instrumented twin of
``EpochEngine.run``: same replay, same result dict, plus a trace with

  * a ``config`` instant — the content hash of the engine config's
    static key (two runs with the same hash compiled the same replay);
  * a ``stages`` instant — the static feature flags the jaxpr was
    gated on (the compile-time answer to "what is in this trace?");
  * ``prepare`` / ``compile`` / ``execute`` / ``assemble`` spans —
    compile wall time is split from execute by lowering the cached
    jitted replay explicitly, so cold-vs-warm runs are legible;
  * a ``jit_entries`` instant — host→device re-entries this replay
    (the engine's one-jit-entry invariant, measured not assumed).

The chaos harness (``repro.chaos.harness``) appends its nemesis
actions and per-round invariant verdicts to the same tracer, so a
failed chaos run reads as a timeline, not a pass/fail bit.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import time
from typing import Any

# Required keys of every exported trace event (the JSON schema the
# round-trip tests and the CI smoke validate).
EVENT_KEYS = ("name", "ph", "ts", "pid", "tid")
TRACE_SCHEMA = "repro-obs-trace/v1"


def config_hash(config) -> str:
    """Content hash of an ``EngineConfig``'s static identity.

    Hashes the same ``_key()`` tuple that keys the compiled-replay
    cache, so equal hashes ⇒ the same jitted program (topology and
    fault-mask bytes included)."""
    return hashlib.sha256(repr(config._key()).encode()).hexdigest()[:16]


def stage_flags(config) -> dict[str, bool]:
    """The static feature gates of one configuration's jaxpr.

    Mirrors the Python-level gating in
    ``repro.engine.replay.unified_runner`` — a disabled stage does not
    exist in the compiled trace at all."""
    gossip, faults = config.gossip, config.faults
    faults_on = faults is not None
    d_on = (
        config.durability is not None and config.durability.enabled
        and faults_on
    )
    return {
        "faults": faults_on,
        "crashes": faults_on and faults.has_crashes,
        "geo": config.topology is not None,
        "gossip": gossip is not None and gossip.enabled,
        "handoff": gossip is not None and gossip.handoff and faults_on,
        "durability": d_on,
        "wal": d_on and config.durability.wal,
        "snapshot": d_on and config.durability.snapshot_every > 0,
        "sharded": config.n_shards > 1,
        "lean": config.lean,
        "obs": config.obs is not None and config.obs.enabled,
    }


class Tracer:
    """Chrome-trace-event collector (complete events + instants).

    Timestamps are microseconds of wall clock relative to the tracer's
    birth; spans are ``ph="X"`` complete events, instants ``ph="i"``.
    One process, one thread lane — the engine lifecycle is sequential
    by construction.
    """

    def __init__(self, run_id: str = "replay"):
        self.run_id = run_id
        self.events: list[dict[str, Any]] = []
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _event(self, name: str, ph: str, ts: float, **fields) -> dict:
        ev = {"name": name, "ph": ph, "ts": ts, "pid": 1, "tid": 1}
        ev.update(fields)
        self.events.append(ev)
        return ev

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """``with tracer.span("compile"): ...`` — one complete event."""
        t0 = self._now_us()
        try:
            yield self
        finally:
            self._event(
                name, "X", t0, dur=self._now_us() - t0, args=args
            )

    def instant(self, name: str, **args) -> None:
        self._event(name, "i", self._now_us(), s="g", args=args)

    # -- export -----------------------------------------------------------

    def chrome(self) -> dict[str, Any]:
        """The Chrome trace-event JSON object."""
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA, "run_id": self.run_id},
        }

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f, indent=1)

    def write_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")


def validate_chrome(obj: dict[str, Any]) -> list[dict[str, Any]]:
    """Check an exported trace against the event schema; returns the
    events.  Raises ``ValueError`` on the first malformed event — the
    CI smoke and the round-trip tests call this on re-loaded JSON."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace-event object")
    events = obj["traceEvents"]
    for i, ev in enumerate(events):
        missing = [k for k in EVENT_KEYS if k not in ev]
        if missing:
            raise ValueError(f"event {i} missing keys {missing}: {ev}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"complete event {i} missing dur: {ev}")
    return events


def load_chrome(path) -> list[dict[str, Any]]:
    """Load + validate a written Chrome trace; returns its events."""
    with open(path) as f:
        return validate_chrome(json.load(f))


def traced_run(engine, w, tracer: Tracer | None = None):
    """``EpochEngine.run`` with the lifecycle traced; ``(result,
    tracer)``.

    Accepts an ``EpochEngine`` or a bare ``EngineConfig``.  The
    single-stack path lowers the cached jitted replay explicitly so
    compile and execute wall time land in separate spans; the sharded
    path (vmap over shard stacks) keeps them fused in one ``replay``
    span.
    """
    import jax
    import jax.numpy as jnp

    from repro.engine import EpochEngine, results
    from repro.engine import replay as replay_mod

    if not isinstance(engine, EpochEngine):
        engine = EpochEngine(engine)
    c = engine.config
    tracer = tracer or Tracer()
    tracer.instant(
        "config", hash=config_hash(c), level=str(c.level),
        n_ops=c.n_ops, batch_size=c.batch_size, n_shards=c.n_shards,
    )
    tracer.instant("stages", **stage_flags(c))
    j0 = replay_mod.jit_entries()
    if c.n_shards > 1:
        with tracer.span("replay", shards=c.n_shards):
            prep = engine.replay(w)
            jax.block_until_ready(prep["out"])
    else:
        with tracer.span("prepare"):
            prep = engine.prepare(w)
            b = {k: jnp.asarray(v) for k, v in prep["batched"][0].items()}
            t = {k: jnp.asarray(v) for k, v in prep["tails"][0].items()}
        run = prep["run"]
        with tracer.span("compile"):
            compiled = run.jitted.lower(b, t).compile()
        with tracer.span("execute"):
            replay_mod._JIT_ENTRIES[0] += 1
            out = jax.block_until_ready(compiled(b, t))
        per_round = None
        if isinstance(out, tuple):
            out, per_round = out
        prep["out"] = out
        prep["per_round"] = per_round
    tracer.instant("jit_entries", count=replay_mod.jit_entries() - j0)
    with tracer.span("assemble"):
        result = results.assemble(engine, prep, w)
    return result, tracer
