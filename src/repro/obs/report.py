"""Per-run observability reports and the ``repro.obs.report`` CLI.

Renders what the device-resident plane measured — per-level percentile
tables, violation-severity CDFs, counters, and the eq. 8 cost
attribution — from result dicts that carry an ``"obs"`` block
(``run_protocol*(..., obs=ObsConfig())``).  Results round-trip through
a JSON artifact so reports re-render without re-running the engine:

    python -m repro.obs.report artifacts/run.json
    python -m repro.obs.report --selftest

``benchmarks/bench_protocol.py`` uses :func:`bench_rows` to turn a
run's obs block into the ``protocol_p99_*`` / ``protocol_severity_*``
rows of BENCH_PROTOCOL.json, and CI runs ``--selftest`` as the obs
smoke: an obs-on/off bit-identity check, a traced replay with a
validated Chrome export, and a rendered report, end to end.
"""

from __future__ import annotations

import json
from typing import Any

ARTIFACT_SCHEMA = "repro-obs-report/v1"


# -- artifacts ------------------------------------------------------------


def write_artifact(path, runs: dict[str, dict[str, Any]]) -> None:
    """Persist named run results (underscore keys stripped — engine
    state handles are not JSON)."""
    clean = {
        name: {k: v for k, v in result.items() if not k.startswith("_")}
        for name, result in runs.items()
    }
    with open(path, "w") as f:
        json.dump({"schema": ARTIFACT_SCHEMA, "runs": clean}, f, indent=1)


def load_artifact(path) -> dict[str, dict[str, Any]]:
    with open(path) as f:
        obj = json.load(f)
    if obj.get("schema") != ARTIFACT_SCHEMA:
        raise ValueError(
            f"{path}: schema {obj.get('schema')!r} != {ARTIFACT_SCHEMA!r}"
        )
    return obj["runs"]


# -- bench rows -----------------------------------------------------------


def bench_rows(name: str, result: dict[str, Any]) -> dict[str, float]:
    """The BENCH_PROTOCOL.json rows of one obs-carrying result.

    ``protocol_p99_<name>`` is the p99 staleness age (merge epochs a
    read lagged the write frontier); ``protocol_severity_<name>`` the
    p99 violation severity.  Histogram percentiles are finite by
    construction (empty distributions floor at ``lo``)."""
    m = result["obs"]["metrics"]
    return {
        f"protocol_p99_{name}": float(m["staleness_age"]["p99"]),
        f"protocol_severity_{name}": float(
            m["violation_severity"]["p99"]
        ),
    }


# -- rendering ------------------------------------------------------------


def _cdf_points(entry: dict[str, Any], max_points: int = 6) -> list:
    """(edge, cumulative fraction) support points of one histogram."""
    counts = entry["hist"]
    total = entry["count"]
    if total == 0:
        return []
    width = (entry["hi"] - entry["lo"]) / entry["n_bins"]
    points, cum = [], 0
    for i, c in enumerate(counts):
        cum += c
        if c:
            points.append((entry["lo"] + (i + 1) * width, cum / total))
    if len(points) > max_points:
        stride = -(-len(points) // max_points)
        points = points[::stride] + [points[-1]]
    return points


def render(runs: dict[str, dict[str, Any]]) -> str:
    """The human-readable report of named obs-carrying results."""
    lines = ["observability report", "=" * 20, ""]
    named = [
        (name, r) for name, r in runs.items() if isinstance(r, dict)
        and "obs" in r
    ]
    if not named:
        return "\n".join(lines + ["(no runs carry an obs block)"])

    lines.append("percentiles")
    lines.append(
        f"  {'run':<14} {'metric':<20} {'count':>8} "
        f"{'p50':>9} {'p90':>9} {'p99':>9}"
    )
    for name, r in named:
        for metric, e in r["obs"]["metrics"].items():
            lines.append(
                f"  {name:<14} {metric:<20} {e['count']:>8} "
                f"{e['p50']:>9.1f} {e['p90']:>9.1f} {e['p99']:>9.1f}"
            )
    lines.append("")

    lines.append("violation severity CDF (age -> fraction of violations)")
    for name, r in named:
        pts = _cdf_points(r["obs"]["metrics"]["violation_severity"])
        if pts:
            body = "  ".join(f"<={e:g}: {f:.2f}" for e, f in pts)
        else:
            body = "(no violations)"
        lines.append(f"  {name:<14} {body}")
    lines.append("")

    lines.append("counters")
    for name, r in named:
        c = r["obs"]["counters"]
        body = "  ".join(f"{k}={v}" for k, v in sorted(c.items()))
        lines.append(f"  {name:<14} {body}")
    lines.append("")

    lines.append("cost attribution (eq. 8 dollars by subsystem)")
    for name, r in named:
        attr = r["obs"].get("cost_attribution") or {}
        body = "  ".join(
            f"{k}=${v:.3g}" for k, v in sorted(attr.items())
        )
        lines.append(f"  {name:<14} {body or '(no cost block)'}")
    lines.append("")

    for name, r in named:
        fve = r["obs"].get("first_violation_epoch")
        if fve is not None:
            lines.append(f"  {name}: first violating epoch = {fve}")
    return "\n".join(lines)


# -- selftest (the CI obs smoke) ------------------------------------------


def selftest(tmpdir=None, n_ops: int = 512) -> str:
    """Obs-on/off bit-identity + trace export + report, end to end.

    Raises on any breach; returns the rendered report.  Kept small
    enough for a CI smoke step (one flat replay per obs setting plus
    one traced replay).
    """
    import tempfile
    from pathlib import Path

    from repro.core.consistency import ConsistencyLevel
    from repro.engine import EngineConfig
    from repro.obs import trace as trace_lib
    from repro.obs.metrics import ObsConfig
    from repro.storage.simulator import run_protocol
    from repro.storage.ycsb import WORKLOAD_A

    tmpdir = Path(tmpdir or tempfile.mkdtemp(prefix="obs-selftest-"))
    level = ConsistencyLevel.X_STCC
    kw = dict(n_ops=n_ops, batch_size=128)

    base = run_protocol(level, WORKLOAD_A, **kw)
    on = run_protocol(level, WORKLOAD_A, **kw, obs=ObsConfig())
    obs_block = on.pop("obs")
    if base != on:
        raise AssertionError(
            "obs=ObsConfig() changed protocol results: "
            f"{base} != {on}"
        )
    on["obs"] = obs_block

    config = EngineConfig(level, obs=ObsConfig(), **kw)
    result, tracer = trace_lib.traced_run(config, WORKLOAD_A)
    trace_path = tmpdir / "trace.json"
    tracer.write_chrome(trace_path)
    tracer.write_jsonl(tmpdir / "trace.jsonl")
    events = trace_lib.load_chrome(trace_path)
    names = {e["name"] for e in events}
    for required in ("config", "stages", "execute", "jit_entries"):
        if required not in names:
            raise AssertionError(f"trace missing {required!r} event")
    (entries,) = [
        e["args"]["count"] for e in events if e["name"] == "jit_entries"
    ]
    if entries != 1:
        raise AssertionError(f"replay took {entries} jit entries, not 1")

    artifact = tmpdir / "runs.json"
    write_artifact(artifact, {"flat": on, "traced": result})
    report = render(load_artifact(artifact))
    if "staleness_age" not in report:
        raise AssertionError("report did not render the age table")
    return report


def main(argv=None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render observability reports from run artifacts.",
    )
    parser.add_argument(
        "artifacts", nargs="*",
        help="JSON artifacts written by repro.obs.report.write_artifact",
    )
    parser.add_argument(
        "--selftest", action="store_true",
        help="run the obs smoke (bit-identity, trace export, report)",
    )
    args = parser.parse_args(argv)
    if not args.selftest and not args.artifacts:
        parser.error("pass an artifact path or --selftest")
    if args.selftest:
        print(selftest())
        print("\nobs selftest OK")
    for path in args.artifacts:
        print(render(load_artifact(path)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
