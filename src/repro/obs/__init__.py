"""Observability plane: device-resident metrics, traces, and reports.

  * :mod:`repro.obs.metrics` — the typed metric registry whose
    histogram/counter state lives in the unified engine's scan carry;
  * :mod:`repro.obs.trace`   — host-side span tracing of the engine
    lifecycle (Chrome trace-event JSON + JSONL);
  * :mod:`repro.obs.report`  — per-run report rendering and the
    ``python -m repro.obs.report`` CLI.

Only the registry is imported eagerly: ``engine.config`` needs
:class:`ObsConfig` before the engine (which ``trace``/``report`` build
on) exists.
"""

from repro.obs.metrics import (  # noqa: F401
    COUNTERS,
    PERCENTILES,
    HostHistogram,
    MetricSpec,
    ObsConfig,
    build_metrics,
    host_percentile,
    summarize,
)
