"""Typed metric registry for the device-resident observability plane.

The unified epoch engine (``repro.engine.replay``) threads an ``obs``
block through its scan carry: one ``(M, n_bins)`` int32 histogram
matrix — one row per registered distribution metric — plus a small dict
of int32 counters.  Everything here is shape bookkeeping *around* that
state: which metrics a configuration records (:func:`build_metrics`),
how their bin ranges pack into kernel params, and how the final carry
summarizes into percentile tables (:func:`summarize`).  The binning
itself is ``repro.kernels.ops.histogram`` (Pallas kernel / jnp twin /
dense oracle, bit-exact), so a whole replay accumulates its
distributions device-side in one jit entry.

The registry is *static per configuration*: the metric row order is a
pure function of :class:`ObsConfig` plus the engine's compile-time
feature flags, so the scan carry layout never depends on data and the
result epilogue can rebuild the same registry host-side.

Distribution metrics (fixed row order):

  ``staleness_age``       resource write frontier minus the served
                          version, per read — the age distribution the
                          timed-consistency papers bound (Δ sits on its
                          upper tail);
  ``violation_severity``  the same ages masked to reads the audit
                          flags as violations — the paper's severity
                          analysis, as a distribution;
  ``read_latency_ms``     RTT of each read's (client region, serving
                          replica region) pair — geo topologies only;
  ``hint_depth``          per-replica hinted-handoff queue depth
                          sampled each epoch — handoff + faults only.

Host-side mirrors: :class:`HostHistogram` gives the serving tier the
same bins/percentile semantics over numpy accumulators, and the
``window_*`` primitives are the one ring-buffer implementation the
policy controllers' telemetry windows are built from.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

# Percentiles every summary/report renders, in order.
PERCENTILES = (50.0, 90.0, 99.0)

# Counter keys of the obs carry block, in registry order.
COUNTERS = ("ops", "reads", "writes", "stale", "viol", "epochs")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """The observability plane's knobs — hashable, content-keyed.

    ``EngineConfig.obs`` holds one of these (default ``None``: the
    engine compiles no obs state at all and its trace is bit-identical
    to the pre-obs engine).  ``n_bins`` is shared by every metric row;
    the ``*_hi`` bounds pick each metric's bin range (observations at
    or above saturate into the top bin — the percentile floor, never an
    overflow).  ``impl`` forwards to ``ops.histogram`` ("pallas" /
    "tiled" / "dense"; ``None`` auto-selects per backend).
    """

    enabled: bool = True
    n_bins: int = 64
    age_hi: float = 1024.0
    latency_hi_ms: float = 512.0
    depth_hi: float = 1024.0
    impl: str | None = None

    def __post_init__(self) -> None:
        if self.n_bins < 2:
            raise ValueError(f"n_bins must be >= 2, got {self.n_bins}")
        for name in ("age_hi", "latency_hi_ms", "depth_hi"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")


class MetricSpec(NamedTuple):
    """One registered distribution metric (one histogram row)."""

    name: str
    lo: float
    hi: float
    per_op: bool   # True: one observation per op; False: per epoch state
    mask: str      # which observations count (documentation only)


def build_metrics(
    obs: ObsConfig, *, geo_on: bool, h_on: bool,
) -> tuple[MetricSpec, ...]:
    """The metric registry of one engine configuration.

    Deterministic row order — per-op metrics first (they bin the same
    ``(B,)`` batch in one kernel call), then per-epoch state metrics —
    so the scan carry and the host-side epilogue agree on layout.
    """
    specs = [
        MetricSpec("staleness_age", 0.0, obs.age_hi, True, "reads"),
        MetricSpec("violation_severity", 0.0, obs.age_hi, True,
                   "violations"),
    ]
    if geo_on:
        specs.append(MetricSpec(
            "read_latency_ms", 0.0, obs.latency_hi_ms, True, "reads"
        ))
    if h_on:
        specs.append(MetricSpec(
            "hint_depth", 0.0, obs.depth_hi, False, "replicas"
        ))
    return tuple(specs)


def batch_bounds(
    specs: tuple[MetricSpec, ...],
) -> tuple[np.ndarray, np.ndarray, int]:
    """(lo, hi, count) of the per-op metric rows, as kernel inputs."""
    per_op = [s for s in specs if s.per_op]
    lo = np.asarray([s.lo for s in per_op], np.float32)
    hi = np.asarray([s.hi for s in per_op], np.float32)
    return lo, hi, len(per_op)


def summarize(
    obs: ObsConfig,
    specs: tuple[MetricSpec, ...],
    hist: np.ndarray,          # (M, n_bins) int32 — final carry state
    counters: dict[str, int],
) -> dict:
    """The per-run obs summary dict (the report/bench feed).

    Percentiles use the cumulative-bin rank semantics of
    ``repro.kernels.histogram.hist_percentile`` (lower bin edge, empty
    histograms report ``lo`` so the bench gates stay finite).
    """
    hist = np.asarray(hist)
    metrics = {}
    for row, spec in enumerate(specs):
        counts = hist[row]
        width = (spec.hi - spec.lo) / obs.n_bins
        entry = {
            "lo": spec.lo,
            "hi": spec.hi,
            "n_bins": obs.n_bins,
            "mask": spec.mask,
            "count": int(counts.sum()),
            "hist": counts.tolist(),
        }
        for q in PERCENTILES:
            entry[f"p{q:g}"] = float(host_percentile(
                counts, spec.lo, width, q
            ))
        metrics[spec.name] = entry
    return {
        "n_bins": obs.n_bins,
        "metrics": metrics,
        "counters": {k: int(v) for k, v in counters.items()},
    }


# -- host-side mirrors ----------------------------------------------------


def host_percentile(
    counts: np.ndarray, lo: float, width: float, q: float,
) -> float:
    """numpy mirror of ``kernels.histogram.hist_percentile`` (same
    lower-edge rank semantics, same empty-histogram floor)."""
    counts = np.asarray(counts, np.int64)
    n = int(counts.sum())
    if n == 0:
        return float(lo)
    rank = int(np.floor(q / 100.0 * np.float32(n - 1)))
    idx = int(np.sum(np.cumsum(counts) <= rank))
    return float(lo + min(idx, counts.shape[0] - 1) * width)


class HostHistogram:
    """Fixed-bin histogram over numpy accumulators — the serving tier's
    per-region latency state, with the device plane's exact bin and
    percentile semantics (saturating edge bins, lower-edge ranks)."""

    def __init__(self, lo: float, hi: float, n_bins: int = 64):
        if n_bins < 2 or hi <= lo:
            raise ValueError(f"bad histogram range [{lo}, {hi}) x {n_bins}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.n_bins = int(n_bins)
        self.width = (self.hi - self.lo) / self.n_bins
        self.counts = np.zeros(self.n_bins, np.int64)

    def observe(self, values, weights=None) -> None:
        values = np.atleast_1d(np.asarray(values, np.float32))
        idx = np.clip(
            np.floor((values - self.lo) / self.width).astype(np.int64),
            0, self.n_bins - 1,
        )
        if weights is None:
            np.add.at(self.counts, idx, 1)
        else:
            np.add.at(
                self.counts, idx,
                np.atleast_1d(np.asarray(weights, np.int64)),
            )

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def percentile(self, q: float) -> float:
        return host_percentile(self.counts, self.lo, self.width, q)

    def summary(self) -> dict:
        out = {"count": self.count}
        for q in PERCENTILES:
            out[f"p{q:g}"] = self.percentile(q)
        return out


# -- telemetry window primitives ------------------------------------------
#
# The one ring-buffer implementation behind every sliding telemetry
# window: the policy controllers' bandit state (ControllerState /
# CadenceState) records epochs and aggregates windowed sums through
# these, so their forgetting semantics cannot drift apart.  jnp-typed
# and jit/scan-safe (imported lazily to keep this module usable from
# config code without touching jax).


def window_init(window: int, shape: tuple[int, ...], dtype=None):
    """A zeroed ``(window, *shape)`` ring."""
    import jax.numpy as jnp

    return jnp.zeros((window, *shape), dtype or jnp.float32)


def window_record(win, ptr, sample):
    """Overwrite slot ``ptr % window`` with this epoch's sample (old
    evidence in that slot ages out — the bandit forgetting scheme)."""
    return win.at[ptr % win.shape[0]].set(sample)


def window_total(win):
    """Windowed sum over the ring axis."""
    import jax.numpy as jnp

    return jnp.sum(win, axis=0)
