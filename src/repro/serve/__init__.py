from repro.serve.engine import (
    ReplicaSnapshot,
    RetryPolicy,
    ServeSession,
    ServeTimeout,
    ServingEngine,
)

__all__ = [
    "ReplicaSnapshot",
    "RetryPolicy",
    "ServeSession",
    "ServeTimeout",
    "ServingEngine",
]
