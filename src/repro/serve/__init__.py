from repro.serve.engine import ReplicaSnapshot, ServeSession, ServingEngine

__all__ = ["ReplicaSnapshot", "ServeSession", "ServingEngine"]
