"""Batched serving engine with session-guarantee-aware replica routing.

The paper's Fig. 2 scenario for model serving: several serving replicas
(pods) each hold a parameter snapshot at some version; request *sessions*
must see monotonically-fresh models (MR) and their own effects (RYW —
e.g. a session that triggered an adapter/weights refresh must see it).
The router implements exactly the X-STCC client-side check: a replica is
admissible for a session iff its version >= the session floor; weaker
levels skip the check and stale serving becomes observable.

The compute path (prefill/decode) is the model substrate; this module
owns the jit'd step functions and the routing/bookkeeping.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.consistency import ConsistencyLevel
from repro.models.model_zoo import Model

Array = jax.Array


@dataclasses.dataclass
class ServeSession:
    session_id: int
    read_floor: int = 0  # min model version this session may observe


@dataclasses.dataclass
class ReplicaSnapshot:
    params: Any
    version: int


class ServingEngine:
    def __init__(
        self,
        model: Model,
        level: ConsistencyLevel = ConsistencyLevel.X_STCC,
        jit: bool = True,
    ):
        self.model = model
        self.level = level
        self.replicas: list[ReplicaSnapshot] = []
        self.stale_serves = 0
        self.total_serves = 0
        self.reroutes = 0
        if jit:
            self._prefill = jax.jit(model.prefill)
            self._decode = jax.jit(model.decode_step)
        else:
            self._prefill = model.prefill
            self._decode = model.decode_step

    # -- replica management -----------------------------------------------------

    def publish(self, params, version: int, replica: int | None = None):
        """Install a parameter snapshot on one replica (or append new)."""
        snap = ReplicaSnapshot(params=params, version=version)
        if replica is None or replica >= len(self.replicas):
            self.replicas.append(snap)
        else:
            self.replicas[replica] = snap

    def publish_everywhere(self, params, version: int):
        for r in range(len(self.replicas)):
            self.replicas[r] = ReplicaSnapshot(params, version)

    @property
    def latest_version(self) -> int:
        return max((r.version for r in self.replicas), default=0)

    # -- routing ------------------------------------------------------------------

    def route(self, session: ServeSession, preferred: int | None = None) -> int:
        """Pick a replica for this session per the consistency level."""
        n = len(self.replicas)
        if n == 0:
            raise RuntimeError("no replicas published")
        idx = (session.session_id if preferred is None else preferred) % n
        if self.level.is_session_guarded:
            if self.replicas[idx].version < session.read_floor:
                # Reroute to the freshest admissible replica (MR/RYW).
                best = max(range(n), key=lambda r: self.replicas[r].version)
                if self.replicas[best].version < session.read_floor:
                    raise RuntimeError("no admissible replica for session")
                self.reroutes += 1
                idx = best
        return idx

    def _observe(self, session: ServeSession, replica: int):
        v = self.replicas[replica].version
        self.total_serves += 1
        if v < self.latest_version:
            self.stale_serves += 1
        session.read_floor = max(session.read_floor, v)

    # -- compute ---------------------------------------------------------------

    def prefill(self, session: ServeSession, batch: dict,
                preferred: int | None = None):
        r = self.route(session, preferred)
        self._observe(session, r)
        logits, cache = self._prefill(self.replicas[r].params, batch)
        return logits, cache, r

    def decode(self, session: ServeSession, cache, tokens,
               replica: int):
        """Decode continues on the session's bound replica (KV cache
        affinity); version floors were checked at prefill."""
        self.total_serves += 1
        return self._decode(self.replicas[replica].params, cache, tokens)

    def generate(self, session: ServeSession, batch: dict, n_tokens: int,
                 preferred: int | None = None):
        """Greedy generation helper for examples/tests."""
        logits, cache, r = self.prefill(session, batch, preferred)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(n_tokens - 1):
            logits, cache = self.decode(session, cache, tok, r)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1), r

    # -- metrics -----------------------------------------------------------------

    def staleness_rate(self) -> float:
        return self.stale_serves / max(1, self.total_serves)
