"""Batched serving engine with session-guarantee-aware replica routing.

The paper's Fig. 2 scenario for model serving: several serving replicas
(pods) each hold a parameter snapshot at some version; request *sessions*
must see monotonically-fresh models (MR) and their own effects (RYW —
e.g. a session that triggered an adapter/weights refresh must see it).
The router implements exactly the X-STCC client-side check: a replica is
admissible for a session iff its version >= the session floor; weaker
levels skip the check and stale serving becomes observable.

All floor/version bookkeeping lives in a
:class:`repro.core.replicated_store.ReplicatedStore` (replicas = snapshot
servers, clients = sessions, the single resource = the model): publishes
are server-side ``install``\\ s, serves are batched session reads, and the
batched router (:meth:`ServingEngine.route_batch`) runs the admission
check through the Pallas session-floor kernel at serving scale.

Consistency is **per session**, not per engine: the engine-level
``level`` is only the default, and :meth:`ServingEngine.set_session_level`
(or an attached :class:`repro.policy.AdaptiveController`, via
:meth:`~ServingEngine.attach_controller` / :meth:`~ServingEngine.adapt_sessions`)
moves individual sessions between consistency levels while they share
the one replicated store — the serving half of the adaptive consistency
control plane.

The compute path (prefill/decode) is the model substrate; this module
owns the jit'd step functions and the routing/bookkeeping.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.consistency import ConsistencyLevel
from repro.core.replicated_store import ReplicatedStore, ShardedStore
from repro.models.model_zoo import Model
from repro.obs.metrics import HostHistogram

Array = jax.Array


@dataclasses.dataclass
class ServeSession:
    session_id: int
    read_floor: int = 0  # min model version this session may observe


@dataclasses.dataclass
class ReplicaSnapshot:
    params: Any
    version: int


class ServeTimeout(RuntimeError):
    """A request exhausted its retry/backoff budget without a serve."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Client-side retry/timeout/backoff contract for routed serves.

    A request that cannot be admitted (no live replica, or no replica
    fresh enough for the session's floor — e.g. its home replica is
    mid-rebuild after a crash) waits out a **jittered exponential
    backoff** and retries, up to ``max_retries`` attempts or until the
    cumulative simulated wait would exceed ``timeout_ms``.  When the
    budget runs out, ``degrade=True`` admits the request once in
    **degraded mode** — the freshest live replica with floor
    enforcement off, i.e. a temporary fallback to an unguarded level —
    and ``degrade=False`` raises :class:`ServeTimeout`.

    Waits are *simulated* (accumulated in the engine's
    ``retry_wait_ms`` telemetry, never slept), so retry behavior is
    deterministic per ``seed`` and free to test.
    """

    max_retries: int = 3
    base_backoff_ms: float = 5.0
    backoff_mult: float = 2.0
    jitter: float = 0.5
    timeout_ms: float = 1000.0
    degrade: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_backoff_ms <= 0 or self.backoff_mult < 1.0:
            raise ValueError(
                "base_backoff_ms must be > 0 and backoff_mult >= 1"
            )
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def backoff_ms(self, attempt: int, rng: np.random.Generator) -> float:
        """The jittered wait before retry ``attempt`` (0-indexed)."""
        base = self.base_backoff_ms * self.backoff_mult ** attempt
        if self.jitter:
            base *= 1.0 + self.jitter * float(rng.uniform(-1.0, 1.0))
        return base


class ServingEngine:
    def __init__(
        self,
        model: Model,
        level: ConsistencyLevel = ConsistencyLevel.X_STCC,
        jit: bool = True,
        max_replicas: int = 8,
        max_sessions: int = 64,
    ):
        self.model = model
        self.level = level
        self.replicas: list[ReplicaSnapshot] = []
        self.max_replicas = max_replicas
        self.max_sessions = max_sessions
        self.stale_serves = 0
        self.total_serves = 0
        self.reroutes = 0
        self.failovers = 0
        # Retry/backoff telemetry (serve_with_retry).
        self.retries = 0
        self.timeouts = 0
        self.downgrades = 0
        self.retry_wait_ms = 0.0
        # Replica liveness (NodeHealth-driven): down replicas are
        # inadmissible for every session and requests fail over.
        self.replica_up = np.ones(max_replicas, bool)
        # Crash-recovery: a replica that is restoring/bootstrapping is
        # reachable but serves nothing until finish_rebuilding().
        self.replica_rebuilding = np.zeros(max_replicas, bool)
        # Region-aware routing (set_topology): replica→region map, RTT
        # matrix, per-session region assignment, per-region telemetry.
        self._topology = None
        self._session_region: np.ndarray | None = None
        self._rtt_np: np.ndarray | None = None
        self._replica_region_np: np.ndarray | None = None
        self._region_stale: np.ndarray | None = None
        self._region_serves: np.ndarray | None = None
        self._region_lat_ms: np.ndarray | None = None
        self._region_hist: list[HostHistogram] | None = None
        # Per-session overrides of the engine default, plus per-session
        # serve telemetry (stale/violation/serve counts since the last
        # controller consultation) feeding `adapt_sessions`.
        self.session_levels: dict[int, ConsistencyLevel] = {}
        self._sess_stale = np.zeros(max_sessions, np.int64)
        self._sess_viol = np.zeros(max_sessions, np.int64)
        self._sess_serves = np.zeros(max_sessions, np.int64)
        self._controller = None
        self._ctl_state = None
        self._ctl_key = None
        self._store = ReplicatedStore(
            max_replicas, max_sessions, 1, level=level,
            pending_cap=max_sessions,
        )
        self._st = self._store.init()
        if jit:
            self._prefill = jax.jit(model.prefill)
            self._decode = jax.jit(model.decode_step)
        else:
            self._prefill = model.prefill
            self._decode = model.decode_step

    def _sid(self, session: ServeSession) -> int:
        if session.session_id >= self.max_sessions:
            # Silent modular aliasing would make colliding sessions
            # share one floor, breaking per-session MR/RYW.
            raise RuntimeError(
                f"session_id {session.session_id} >= max_sessions "
                f"{self.max_sessions}; raise max_sessions"
            )
        return session.session_id

    # -- replica management -----------------------------------------------------

    def publish(self, params, version: int, replica: int | None = None):
        """Install a parameter snapshot on one replica (or append new)."""
        snap = ReplicaSnapshot(params=params, version=version)
        if replica is None or replica >= len(self.replicas):
            if len(self.replicas) >= self.max_replicas:
                raise RuntimeError(
                    f"more than max_replicas={self.max_replicas} replicas"
                )
            self.replicas.append(snap)
            replica = len(self.replicas) - 1
        else:
            self.replicas[replica] = snap
        self._st = self._store.install(
            self._st, replica=replica, resource=0, version=version
        )

    def publish_everywhere(self, params, version: int):
        for r in range(len(self.replicas)):
            self.replicas[r] = ReplicaSnapshot(params, version)
            self._st = self._store.install(
                self._st, replica=r, resource=0, version=version
            )

    @property
    def latest_version(self) -> int:
        return max((r.version for r in self.replicas), default=0)

    # -- replica health -----------------------------------------------------------

    def set_replica_health(self, health) -> None:
        """Drive the liveness mask from a health source.

        ``health`` is either a ``repro.runtime.NodeHealth`` (its
        ``alive()`` vector is consumed) or a boolean sequence/array of
        per-replica liveness.  Down replicas become inadmissible in
        :meth:`route` / :meth:`route_batch` and requests fail over.
        """
        if hasattr(health, "alive"):
            health = health.alive()
        up = np.asarray(health, bool)
        if up.shape[0] > self.max_replicas:
            raise ValueError(
                f"health covers {up.shape[0]} replicas, engine has "
                f"max_replicas={self.max_replicas}"
            )
        self.replica_up[: up.shape[0]] = up

    def fail_replica(self, replica: int) -> None:
        self.replica_up[replica] = False

    def heal_replica(self, replica: int) -> None:
        self.replica_up[replica] = True

    def mark_rebuilding(self, replica: int) -> None:
        """Take a replica out of serving while it restores/bootstraps.

        The crash-recovery client: a replica that crashed is *up*
        (reachable for gossip/bootstrap) but must not serve until its
        state is rebuilt — requests targeting it fail over exactly like
        a down replica's would.
        """
        self.replica_rebuilding[replica] = True

    def finish_rebuilding(self, replica: int) -> None:
        """Re-admit a rebuilt replica into serving."""
        self.replica_rebuilding[replica] = False

    def _up(self) -> np.ndarray:
        """Serving-admissible mask: live and not mid-rebuild."""
        n = len(self.replicas)
        up = self.replica_up[:n] & ~self.replica_rebuilding[:n]
        if not up.any():
            raise RuntimeError("no live replica to serve from")
        return up

    # -- region-aware routing -------------------------------------------------------

    def set_topology(self, topology, session_region=None) -> None:
        """Make routing region-aware.

        ``topology`` is a :class:`repro.geo.topology.RegionTopology`
        whose replica map covers this engine's replica slots; sessions
        are pinned to regions by ``session_region`` (any sequence,
        defaulting to the topology's client-population assignment).
        From then on a session's default target is the **nearest live
        replica by RTT** from its region — replacing the
        ``session_id % n`` spread — reroutes prefer the nearest
        admissible replica, and per-region latency/staleness telemetry
        accumulates (:meth:`region_stats`).
        """
        if topology.n_replicas < self.max_replicas:
            raise ValueError(
                f"topology places {topology.n_replicas} replicas, engine "
                f"has max_replicas={self.max_replicas}"
            )
        if session_region is None:
            reg = topology.client_region_of(np.arange(self.max_sessions))
        else:
            reg = np.asarray(session_region, np.int32)
            if reg.shape[0] != self.max_sessions:
                raise ValueError(
                    f"session_region covers {reg.shape[0]} sessions, "
                    f"engine has {self.max_sessions}"
                )
        self._topology = topology
        self._session_region = reg.astype(np.int32)
        # Dense views of the topology tuples, converted once: the geo
        # routing paths argmin over these on every request.
        self._rtt_np = np.asarray(topology.rtt_ms, np.float64)
        self._replica_region_np = topology.regions()
        g = topology.n_regions
        self._region_stale = np.zeros(g, np.int64)
        self._region_serves = np.zeros(g, np.int64)
        self._region_lat_ms = np.zeros(g, np.float64)
        # Per-region serve-latency distributions on the shared obs
        # histogram primitive; RTTs are bounded by the matrix, so the
        # top bin saturates only if the topology is later mutated.
        lat_hi = max(1.0, float(self._rtt_np.max()) * 1.5)
        self._region_hist = [HostHistogram(0.0, lat_hi) for _ in range(g)]

    def _geo_rtts(self, session_ids, n: int) -> np.ndarray:
        """(B, n) RTT from each session's region to replicas ``0..n-1``.

        One matrix gather for the whole batch — the geo routing paths
        below are all argmins over rows of this.
        """
        sregs = self._session_region[np.asarray(session_ids, np.int64)]
        return self._rtt_np[sregs][:, self._replica_region_np[:n]]

    def _geo_preferred(self, session_id: int, n: int) -> int:
        """Nearest replica by RTT from the session's region.

        Deliberately liveness-*ignorant*: this is the session's natural
        target, so a down nearest replica registers as a failover (the
        PR-4 counting contract) before routing falls over to the
        nearest live replica.
        """
        return int(np.argmin(self._geo_rtts([session_id], n)[0]))

    def _geo_failover(self, session_id: int, up: np.ndarray) -> int:
        """Nearest *live* replica by RTT from the session's region."""
        rtts = self._geo_rtts([session_id], up.shape[0])[0]
        return int(np.argmin(np.where(up, rtts, np.inf)))

    def _geo_reroute(
        self, session_id: int, floor: int, up: np.ndarray
    ) -> int:
        """Nearest live *admissible* replica; freshest live fallback."""
        versions = np.asarray([r.version for r in self.replicas])
        adm = up & (versions >= floor)
        if not adm.any():
            return _freshest_replica(self.replicas, up)
        rtts = self._geo_rtts([session_id], up.shape[0])[0]
        return int(np.argmin(np.where(adm, rtts, np.inf)))

    def _note_serve(self, session_id: int, replica: int, stale: int) -> None:
        """Per-region serve telemetry (no-op without a topology)."""
        if self._topology is None:
            return
        sreg = int(self._session_region[session_id])
        rreg = int(self._replica_region_np[replica])
        self._region_serves[sreg] += 1
        self._region_stale[sreg] += stale
        lat = float(self._rtt_np[sreg, rreg])
        self._region_lat_ms[sreg] += lat
        self._region_hist[sreg].observe([lat])

    def region_stats(self) -> dict[str, list[float]]:
        """Per-region serving telemetry (requires :meth:`set_topology`).

        Latency is the RTT-matrix distance between the session's region
        and the replica that served it — the serving-side replacement
        of the two-value ``ack_latency_ms`` step function.  Percentiles
        come from per-region fixed-bin histograms (the shared obs
        primitive), so a failover burst that reroutes the slowest few
        percent of serves moves ``p99_latency_ms`` while
        ``p50_latency_ms`` holds — the mean alone can't show that.
        """
        if self._topology is None:
            raise RuntimeError("no topology set (call set_topology)")
        serves = np.maximum(1, self._region_serves)
        return {
            "serves": self._region_serves.tolist(),
            "stale": self._region_stale.tolist(),
            "staleness_rate": (self._region_stale / serves).tolist(),
            "mean_latency_ms": (self._region_lat_ms / serves).tolist(),
            "p50_latency_ms": [h.percentile(50) for h in self._region_hist],
            "p99_latency_ms": [h.percentile(99) for h in self._region_hist],
        }

    # -- per-session consistency ---------------------------------------------------

    def level_for(self, session_id: int) -> ConsistencyLevel:
        """The session's effective consistency level (default: engine's)."""
        return self.session_levels.get(session_id, self.level)

    def set_session_level(self, session_id: int, level: ConsistencyLevel):
        """Move one session to a different consistency level online."""
        if session_id >= self.max_sessions:
            raise RuntimeError(
                f"session_id {session_id} >= max_sessions {self.max_sessions}"
            )
        self.session_levels[session_id] = level

    def attach_controller(self, controller, key: Array | None = None):
        """Hand per-session level selection to an adaptive controller.

        ``controller`` is a :class:`repro.policy.AdaptiveController`
        sized to this engine's ``max_sessions``; call
        :meth:`adapt_sessions` once per serving epoch to fold the
        accumulated telemetry and re-select levels.
        """
        if controller.n_sessions != self.max_sessions:
            raise ValueError(
                f"controller sized for {controller.n_sessions} sessions, "
                f"engine has {self.max_sessions}"
            )
        if self.level not in controller.levels:
            raise ValueError(
                f"engine default level {self.level} not among controller "
                f"levels {controller.levels}"
            )
        self._controller = controller
        self._ctl_state = controller.init()
        self._ctl_key = jax.random.PRNGKey(0) if key is None else key

    def adapt_sessions(self) -> dict[int, ConsistencyLevel]:
        """One control-plane epoch: observe serve telemetry, re-select.

        Serving is a read-only workload, so ``read_frac`` is 1 and the
        violation telemetry comes from unguarded sessions observing
        reads below their floor.  Returns the new assignment.
        """
        if self._controller is None:
            raise RuntimeError("no controller attached")
        ctl = self._controller
        idx_list = []
        for s in range(self.max_sessions):
            lv = self.level_for(s)
            if lv not in ctl.levels:
                raise RuntimeError(
                    f"session {s} is at level {lv.value}, which is not "
                    f"among the controller's levels "
                    f"{[l.value for l in ctl.levels]}; use "
                    "set_session_level with a controller level (or a "
                    "controller whose level set covers it)"
                )
            idx_list.append(ctl.levels.index(lv))
        idx = jnp.asarray(idx_list, jnp.int32)
        self._ctl_state = ctl.observe(
            self._ctl_state,
            level_idx=idx,
            stale=jnp.asarray(self._sess_stale, jnp.float32),
            viol=jnp.asarray(self._sess_viol, jnp.float32),
            reads=jnp.asarray(self._sess_serves, jnp.float32),
        )
        self._ctl_key, sub = jax.random.split(self._ctl_key)
        choice = np.asarray(ctl.select(self._ctl_state, sub, read_frac=1.0))
        self._sess_stale[:] = 0
        self._sess_viol[:] = 0
        self._sess_serves[:] = 0
        for sid in range(self.max_sessions):
            self.session_levels[sid] = ctl.levels[int(choice[sid])]
        return dict(self.session_levels)

    # -- routing ------------------------------------------------------------------

    def session_floor(self, session: ServeSession) -> int:
        """MR/RYW floor: store-tracked, joined with any external floor."""
        floor = int(self._store.session_floor(self._st, self._sid(session), 0))
        return max(floor, session.read_floor)

    def route(self, session: ServeSession, preferred: int | None = None) -> int:
        """Pick a replica for this session per *its* consistency level.

        A down replica is inadmissible for every session regardless of
        level: the request fails over to the freshest live replica —
        the same target :meth:`route_batch` picks, so the scalar and
        batched paths route identical traffic identically — counted in
        ``failovers`` and ``reroutes``; the session floors are then
        checked against the failover target.
        """
        n = len(self.replicas)
        if n == 0:
            raise RuntimeError("no replicas published")
        up = self._up()
        if preferred is not None:
            idx = preferred % n
        elif self._topology is not None:
            # Region-aware default: nearest replica by RTT.  Liveness
            # is checked below, so a down nearest replica still counts
            # as a failover.
            idx = self._geo_preferred(session.session_id, n)
        else:
            idx = session.session_id % n
        failed_over = not up[idx]
        if failed_over:
            idx = (
                self._geo_failover(session.session_id, up)
                if self._topology is not None
                else _freshest_replica(self.replicas, up)
            )
            self.failovers += 1
            self.reroutes += 1
        if self.level_for(session.session_id).is_session_guarded:
            floor = self.session_floor(session)
            if self.replicas[idx].version < floor:
                best = (
                    self._geo_reroute(session.session_id, floor, up)
                    if self._topology is not None
                    else _freshest_replica(self.replicas, up)
                )
                if self.replicas[best].version < floor:
                    raise RuntimeError("no admissible replica for session")
                # Reroute to the freshest live admissible replica
                # (MR/RYW); a down+inadmissible serve still counts one
                # reroute, like the batched path's single ~ok.
                if not failed_over:
                    self.reroutes += 1
                idx = best
        return idx

    def serve_with_retry(
        self,
        session: ServeSession,
        preferred: int | None = None,
        policy: RetryPolicy | None = None,
    ) -> int:
        """Route-and-observe one serve under a retry/backoff policy.

        Attempts :meth:`route` + the observe read; an inadmissible
        request (no live replica, or no replica fresh enough for the
        session's floor — e.g. the home replica is mid-rebuild after a
        crash) backs off per ``policy`` and retries.  When the retry
        budget or ``timeout_ms`` runs out: ``policy.degrade`` admits
        the request once on the freshest live replica with floor
        enforcement off (counted in ``downgrades``, and the serve's
        staleness lands in the normal telemetry); otherwise the request
        fails with :class:`ServeTimeout` (counted in ``timeouts``).
        Waits are simulated — accumulated in ``retry_wait_ms`` — so
        the path is deterministic per ``policy.seed`` and session.
        Returns the replica that served.
        """
        if policy is None:
            policy = RetryPolicy()
        rng = np.random.default_rng(
            policy.seed + self._sid(session)
        )
        waited = 0.0
        last_err: RuntimeError | None = None
        for attempt in range(policy.max_retries + 1):
            try:
                r = self.route(session, preferred)
                self._observe(session, r)
                return r
            except RuntimeError as e:
                last_err = e
            if attempt >= policy.max_retries:
                break
            wait = policy.backoff_ms(attempt, rng)
            if waited + wait > policy.timeout_ms:
                break
            waited += wait
            self.retries += 1
            self.retry_wait_ms += wait
        if policy.degrade:
            n = len(self.replicas)
            live = self.replica_up[:n] & ~self.replica_rebuilding[:n]
            if n and live.any():
                r = _freshest_replica(self.replicas, live)
                self.downgrades += 1
                self._observe(session, r, enforce=False)
                return r
        self.timeouts += 1
        raise ServeTimeout(
            f"session {session.session_id}: no admissible replica after "
            f"{policy.max_retries} retries ({waited:.1f} ms backoff)"
        ) from last_err

    def route_batch(
        self, sessions: list[ServeSession], preferred: Array | None = None,
        use_kernel: bool = True,
    ) -> tuple[Array, Array]:
        """Vectorized admission check for a batch of sessions.

        Routes every session to its preferred replica, runs the batched
        session-floor admission check (the Pallas kernel when
        ``use_kernel``), reroutes inadmissible *session-guarded*
        sessions to the freshest live replica (unguarded sessions take
        the stale serve, which is counted as their violation telemetry),
        fails sessions whose preferred replica is down over to the
        freshest live replica, and registers the serves in the store.
        Returns ``(replica_indices, served_versions)``.
        """
        n = len(self.replicas)
        if n == 0:
            raise RuntimeError("no replicas published")
        up = self._up()
        sid = jnp.asarray([self._sid(s) for s in sessions], jnp.int32)
        geo_rtts = (
            self._geo_rtts(np.asarray(sid), n)
            if self._topology is not None else None
        )
        if preferred is None:
            if geo_rtts is not None:
                # Nearest replica by RTT, liveness-ignorant — a down
                # nearest replica counts as a failover below.
                preferred = jnp.asarray(
                    np.argmin(geo_rtts, axis=1), jnp.int32
                )
            else:
                preferred = jnp.asarray(
                    [s.session_id % n for s in sessions], jnp.int32
                )
        preferred = jnp.asarray(preferred, jnp.int32) % n
        guarded = jnp.asarray(
            [self.level_for(s.session_id).is_session_guarded
             for s in sessions],
            bool,
        )
        alive = jnp.asarray(up)[preferred]
        best = _freshest_replica(self.replicas, up)
        if bool(jnp.any(guarded)):
            # Admission against the store-tracked floors (the Pallas
            # kernel path); the returned state is discarded on purpose —
            # floors are only committed by the observe step below, after
            # rerouting decides where each session actually reads.
            _, _, adm = self._store.admit_batch(
                self._st, client=sid, replica=preferred,
                resource=jnp.zeros(sid.shape, jnp.int32),
                use_kernel=use_kernel,
            )
            # Join with any externally-set session floor (route() parity).
            ext = jnp.asarray(
                [s.read_floor for s in sessions], jnp.int32
            )
            versions = jnp.asarray(
                [r.version for r in self.replicas], jnp.int32
            )
            adm = jnp.logical_and(adm, versions[preferred] >= ext)
            adm = jnp.logical_or(adm, ~guarded)
            ok = adm & alive
            floor = jnp.maximum(
                self._store.session_floor(self._st, sid, 0), ext
            )
            if geo_rtts is not None:
                # Per-session reroute target: nearest live admissible
                # replica (freshest live when none admits) — one
                # masked argmin over the precomputed (B, n) RTT rows.
                # Unguarded sessions ignore floors: their only reroute
                # cause is a dead replica, and the target is the
                # nearest live replica — exactly what route() picks,
                # keeping the scalar/batch routing parity.
                adm_at = np.asarray(up)[None, :] & (
                    np.asarray(versions)[None, :]
                    >= np.asarray(floor)[:, None]
                )
                adm_at = np.where(
                    np.asarray(guarded)[:, None], adm_at,
                    np.asarray(up)[None, :],
                )
                target = np.where(
                    adm_at.any(axis=1),
                    np.argmin(np.where(adm_at, geo_rtts, np.inf), axis=1),
                    best,
                )
                best = jnp.asarray(target, jnp.int32)
            if bool(jnp.any(guarded & ~ok & (versions[best] < floor))):
                raise RuntimeError("no admissible replica for session")
        else:
            ok = alive
            if geo_rtts is not None:
                best = jnp.asarray(
                    np.argmin(
                        np.where(np.asarray(up)[None, :n], geo_rtts, np.inf),
                        axis=1,
                    ),
                    jnp.int32,
                )
        replica = jnp.where(ok, preferred, best)
        self.reroutes += int(jnp.sum(~ok))
        self.failovers += int(jnp.sum(~alive))
        served = self._observe_batch(sessions, replica, guarded)
        return replica, served

    def _observe_batch(
        self, sessions: list[ServeSession], replica: Array,
        guarded: Array | None = None,
    ):
        sid = jnp.asarray([self._sid(s) for s in sessions], jnp.int32)
        if guarded is None:
            guarded = jnp.asarray(
                [self.level_for(s.session_id).is_session_guarded
                 for s in sessions],
                bool,
            )
        self._st, res = self._store.read_batch(
            self._st, client=sid, replica=jnp.asarray(replica, jnp.int32),
            resource=jnp.zeros(sid.shape, jnp.int32), record=False,
            enforce=guarded,
        )
        self.total_serves += len(sessions)
        self.stale_serves += int(jnp.sum(res.stale))
        sid_np = np.asarray(sid)
        np.add.at(self._sess_stale, sid_np, np.asarray(res.stale))
        np.add.at(self._sess_viol, sid_np, np.asarray(res.violation))
        np.add.at(self._sess_serves, sid_np, 1)
        if self._topology is not None:
            sregs = self._session_region[sid_np]
            rregs = self._replica_region_np[np.asarray(replica)]
            lat = self._rtt_np[sregs, rregs]
            np.add.at(self._region_serves, sregs, 1)
            np.add.at(
                self._region_stale, sregs,
                np.asarray(res.stale).astype(np.int64),
            )
            np.add.at(self._region_lat_ms, sregs, lat)
            for g in np.unique(sregs):
                self._region_hist[g].observe(lat[sregs == g])
        for s, v in zip(sessions, list(res.version)):
            s.read_floor = max(s.read_floor, int(v))
        return res.version

    def _observe(
        self, session: ServeSession, replica: int,
        enforce: bool | None = None,
    ):
        # Telemetry comes from the store's read result — the same
        # source `_observe_batch` uses, so the scalar and batched
        # routing paths can never disagree about one serve (the old
        # python-side `version < latest_version` check diverged from
        # the store under enforcement and snapshot overwrites).
        # ``enforce`` overrides the session level's guard — the
        # degraded-admission path serves guarded sessions unguarded.
        if enforce is None:
            enforce = self.level_for(session.session_id).is_session_guarded
        self._st, res = self._store.read_batch(
            self._st,
            client=jnp.asarray([self._sid(session)], jnp.int32),
            replica=jnp.asarray([replica], jnp.int32),
            resource=jnp.zeros((1,), jnp.int32),
            record=False,
            enforce=enforce,
        )
        self.total_serves += 1
        self.stale_serves += int(res.stale[0])
        sid = self._sid(session)
        self._sess_stale[sid] += int(res.stale[0])
        self._sess_viol[sid] += int(res.violation[0])
        self._sess_serves[sid] += 1
        self._note_serve(sid, replica, int(res.stale[0]))
        session.read_floor = max(session.read_floor, int(res.version[0]))

    # -- compute ---------------------------------------------------------------

    def prefill(self, session: ServeSession, batch: dict,
                preferred: int | None = None):
        r = self.route(session, preferred)
        self._observe(session, r)
        logits, cache = self._prefill(self.replicas[r].params, batch)
        return logits, cache, r

    def decode(self, session: ServeSession, cache, tokens,
               replica: int):
        """Decode continues on the session's bound replica (KV cache
        affinity); version floors were checked at prefill.  A decode
        step is not a routed serve: it never counts toward
        ``total_serves`` (a serve is counted once per routed request,
        so the engine-level ``staleness_rate`` and the per-session
        telemetry share one denominator)."""
        return self._decode(self.replicas[replica].params, cache, tokens)

    def generate(self, session: ServeSession, batch: dict, n_tokens: int,
                 preferred: int | None = None):
        """Greedy generation helper for examples/tests."""
        logits, cache, r = self.prefill(session, batch, preferred)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(n_tokens - 1):
            logits, cache = self.decode(session, cache, tok, r)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1), r

    # -- metrics -----------------------------------------------------------------

    def staleness_rate(self) -> float:
        return self.stale_serves / max(1, self.total_serves)


def _freshest_replica(
    replicas: list[ReplicaSnapshot], up: np.ndarray | None = None
) -> int:
    """Freshest replica, restricted to live ones when ``up`` is given."""
    live = range(len(replicas)) if up is None else [
        r for r in range(len(replicas)) if up[r]
    ]
    return max(live, key=lambda r: replicas[r].version)


class ShardedServingRouter:
    """Device-sharded admission front door for multi-tenant serving.

    Partitions the session space into ``n_shards`` disjoint tenant
    groups of ``sessions_per_shard`` sessions; each shard owns a full
    replicated store (snapshot replicas × shard sessions × the one
    model resource) stacked along a leading axis
    (:class:`repro.core.replicated_store.ShardedStore`), so the
    admission check, reroute, and floor bookkeeping of a whole
    ``(S, B)`` shard-aligned request batch run as one vmapped program —
    on a multi-device host the shard axis lays out across the device
    mesh exactly like :func:`repro.storage.simulator.run_protocol_sharded`.

    Serving batches are read-only, so disjoint session shards share no
    floor state: routing an ``(S, B)`` batch here is bit-identical to
    routing the concatenated ``S·B`` sessions through one unsharded
    :class:`ServingEngine` (``tests/test_op_ingest.py`` asserts it).
    """

    def __init__(
        self,
        n_shards: int,
        sessions_per_shard: int,
        max_replicas: int = 8,
        level: ConsistencyLevel = ConsistencyLevel.X_STCC,
        age_hi: float = 1024.0,
    ):
        self.n_shards = n_shards
        self.sessions_per_shard = sessions_per_shard
        self.max_replicas = max_replicas
        self.level = level
        self._sharded = ShardedStore(
            ReplicatedStore(
                max_replicas, sessions_per_shard, 1, level=level,
                pending_cap=max(8, sessions_per_shard),
            ),
            n_shards,
        )
        self._st = self._sharded.init()
        self._versions = np.zeros(max_replicas, np.int64)
        self.replica_up = np.ones(max_replicas, bool)
        self.n_replicas = 0
        self.total_serves = 0
        self.stale_serves = 0
        self.reroutes = 0
        self.failovers = 0
        # Staleness-age distribution of every routed serve (latest
        # published version minus served version, in versions).
        self._age_hist = HostHistogram(0.0, float(age_hi))

    def set_replica_health(self, health) -> None:
        """Drive the liveness mask (``NodeHealth`` or a bool vector)."""
        if hasattr(health, "alive"):
            health = health.alive()
        up = np.asarray(health, bool)
        if up.shape[0] > self.max_replicas:
            raise ValueError(
                f"health covers {up.shape[0]} replicas, router has "
                f"max_replicas={self.max_replicas}"
            )
        self.replica_up[: up.shape[0]] = up

    def install(self, replica: int, version: int):
        """Publish a snapshot version on one replica — to every shard.

        Replica ids must be dense (install ``0..n`` in order, or
        overwrite an existing one) — the routing modulus spans
        ``n_replicas``, and a gap would let sessions land on a replica
        that never published (the unsharded engine appends snapshots,
        so it cannot have gaps either).
        """
        if replica >= self.max_replicas:
            raise RuntimeError(
                f"replica {replica} >= max_replicas {self.max_replicas}"
            )
        if replica > self.n_replicas:
            raise RuntimeError(
                f"replica ids must be dense: install replica "
                f"{self.n_replicas} before {replica}"
            )
        self._st = self._sharded.install(
            self._st, replica=replica, resource=0, version=version
        )
        self._versions[replica] = max(self._versions[replica], version)
        self.n_replicas = max(self.n_replicas, replica + 1)

    def route(
        self, session: Array, preferred: Array | None = None
    ) -> tuple[Array, Array]:
        """Route one ``(S, B)`` batch of shard-local session ids.

        Admission against each shard's store floors, reroute of
        inadmissible sessions to the freshest replica (the engine-level
        ``route_batch`` semantics), then the batched observe read that
        raises the floors.  Returns ``(replica, served)`` as ``(S, B)``
        arrays.
        """
        if self.n_replicas == 0:
            raise RuntimeError("no replicas published")
        up = self.replica_up[: self.n_replicas]
        if not up.any():
            raise RuntimeError("no live replica to serve from")
        sid = jnp.asarray(session, jnp.int32)
        if preferred is None:
            preferred = sid % self.n_replicas
        preferred = jnp.asarray(preferred, jnp.int32) % self.n_replicas
        alive = jnp.asarray(up)[preferred]
        # Freshest *live* replica is the failover / reroute target.
        best = int(np.argmax(np.where(up, self._versions[: self.n_replicas],
                                      -1)))

        guarded = self.level.is_session_guarded
        if guarded:
            def admit(st, s, pref):
                cl = st.cluster
                floor = jnp.maximum(
                    cl.read_floor[s, 0], cl.write_floor[s, 0]
                )
                return cl.replica_version[pref, 0] >= floor, floor

            adm, floor = jax.vmap(admit)(self._st, sid, preferred)
            ok = adm & alive
            if bool(jnp.any(~ok & (self._versions[best] < floor))):
                raise RuntimeError("no admissible replica for session")
            replica = jnp.where(ok, preferred, best)
            self.reroutes += int(jnp.sum(~ok))
        else:
            # A failover is a reroute too — same counting as the
            # unsharded engine for identical traffic.
            replica = jnp.where(alive, preferred, best)
            self.reroutes += int(jnp.sum(~alive))
        self.failovers += int(jnp.sum(~alive))
        self._st, res = self._sharded.read_batch(
            self._st, client=sid, replica=replica,
            resource=jnp.zeros(sid.shape, jnp.int32), record=False,
            enforce=guarded,
        )
        self.total_serves += int(sid.size)
        self.stale_serves += int(jnp.sum(res.stale))
        ages = self._versions[: self.n_replicas].max() - np.asarray(
            res.version, np.int64
        )
        self._age_hist.observe(np.maximum(ages, 0).ravel())
        return replica, res.version

    def age_stats(self) -> dict[str, float]:
        """Staleness-age distribution of every serve routed so far.

        Age is how many published versions the served snapshot lagged
        the freshest replica at serve time; percentiles come from the
        shared obs histogram primitive, so a failover that pins a
        tenant group on a stale snapshot shows up as a p99 spike while
        the p50 (the healthy majority) holds.
        """
        return {
            "serves": int(self._age_hist.count),
            "p50_age": self._age_hist.percentile(50),
            "p99_age": self._age_hist.percentile(99),
        }

    def staleness_rate(self) -> float:
        return self.stale_serves / max(1, self.total_serves)
