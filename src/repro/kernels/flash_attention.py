"""Pallas TPU flash attention (forward), GQA-aware, causal + window.

The prefill/train compute hot-spot of every attention architecture in
the pool.  TPU-native design decisions (vs a CUDA port):

  * Tiling is (block_q x block_k) with both dims multiples of 128 so the
    q @ k^T and p @ v contractions land on the MXU at full occupancy.
  * Online softmax state (m, l, acc) lives in VMEM **scratch** that
    persists across the innermost ("arbitrary") grid dimension — the
    standard Pallas accumulation idiom, replacing the CUDA shared-memory
    staging loop.
  * GQA is expressed through the k/v BlockSpec ``index_map`` (query head
    h reads kv head h // group) — no materialized head broadcast.
  * Fully-masked (future) k-blocks are skipped with ``pl.when`` so the
    causal prefill does ~half the block work, like the CUDA kernel's
    early-exit but decided statically from grid indices.

Layouts: q (B, H, S, hd); k/v (B, Hkv, T, hd); out (B, H, S, hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.0 ** 30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, causal: bool, window: int,
    scale: float, n_kblocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # Static-ish skip: a k-block strictly in the future contributes
    # nothing under the causal mask.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    def body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)

        if causal or window > 0:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask = jnp.logical_and(mask, kpos <= qpos)
            if window > 0:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[...]                           # (bq, 1)
        m_new = jnp.maximum(
            m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    if causal:
        pl.when(run)(body)
    else:
        body()

    @pl.when(kj == n_kblocks - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    softmax_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, Hkv, T, hd) -> (B, H, S, hd)."""
    b, h, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    nq = s // block_q
    nk = t // block_k
    scale = (hd ** -0.5) if softmax_scale is None else softmax_scale

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, causal=causal, window=window,
        scale=scale, n_kblocks=nk,
    )
    grid = (b, h, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, kj, g=g: (bi, hi // g, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, kj, g=g: (bi, hi // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            # Online-softmax state persists across the k grid dim: VMEM.
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
