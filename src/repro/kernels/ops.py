"""Jit'd public wrappers around the Pallas kernels.

On a TPU runtime the kernels compile natively; on CPU (this container,
CI) they run in interpret mode — same code path, Python-executed kernel
body — which is how the correctness sweeps in ``tests/test_kernels.py``
validate them against the ``ref.py`` oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import digest_compare as _dc
from repro.kernels import flash_attention as _fa
from repro.kernels import histogram as _hg
from repro.kernels import op_ingest as _oi
from repro.kernels import placement_score as _pls
from repro.kernels import policy_score as _ps
from repro.kernels import session_floor as _sf
from repro.kernels import vclock_audit as _va


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_op_ingest_impl(
    impl: str | None,
    *,
    batch: int,
    n_clients: int | None = None,
    n_replicas: int | None = None,
    n_resources: int | None = None,
    affine_op_index: bool = False,
) -> str:
    """Resolve the ``op_ingest`` implementation for a call shape.

    ``None``/``"auto"`` picks the fastest bit-identical path for the
    backend: the Pallas kernel on TPU; on CPU the closed-form fused path
    (O(B·R + B log B), no pair sweep) whenever the static state sizes
    are known, its packed segment keys fit int32, and the caller
    guarantees batch-affine op indices (``op_index[i] == op_index[0] +
    i`` — every store-layer batch; without cadence inputs the indices
    are irrelevant and fused is always safe); otherwise the tiled block
    walk.  Exposed so the store layer can pre-resolve the impl and feed
    the pending ring to the fused path directly.
    """
    if impl is not None and impl != "auto":
        return impl
    if jax.default_backend() == "tpu":
        return "pallas"
    if None not in (n_clients, n_replicas, n_resources) and affine_op_index:
        max_seg = max(n_clients, n_replicas) * n_resources
        if max_seg * max(batch, 1) < 2 ** 31:
            return "fused"
    return "tiled"


def op_ingest(
    client: jax.Array,     # (B,) int32
    replica: jax.Array,    # (B,) int32
    resource: jax.Array,   # (B,) int32
    is_write: jax.Array,   # (B,) bool
    g0: jax.Array,         # (B,) int32 — global_version gathered per op
    raw0: jax.Array,       # (B,) int32 — replica_version gathered per op
    floor0: jax.Array,     # (B,) int32 — session floor gathered per op
    *,
    op_index: jax.Array | None = None,
    apply_index: jax.Array | None = None,
    pend_version: jax.Array | None = None,
    pend_resource: jax.Array | None = None,
    pend_live: jax.Array | None = None,
    pend_apply: jax.Array | None = None,
    impl: str | None = None,
    block: int | None = None,
    interpret: bool | None = None,
    n_clients: int | None = None,
    n_replicas: int | None = None,
    n_resources: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched op-ingestion prefixes ``(occ, raw, floor)``.

    Same contract as ``repro.kernels.ref.op_ingest_ref`` (bit-exact) —
    the three per-op prefix reductions that ``xstcc.apply_op_batch``
    builds versions, admission, staleness, and floors from.  ``impl``
    selects the implementation:

      * ``"pallas"`` — the tiled TPU kernel (O(B·block) memory);
      * ``"tiled"``  — the jnp ``lax.scan`` twin of the kernel, the
        fast path on CPU where Pallas runs interpreted;
      * ``"fused"``  — the closed-form segmented-scan path (O(B·R +
        B log B), no pair sweep) — needs the static state sizes
        (``n_clients``/``n_replicas``/``n_resources``) and, when cadence
        inputs are present, batch-affine ``op_index``;
      * ``"dense"``  — the O(B²) oracle (the PR-1 masks, kept as the
        fallback and differential baseline);
      * ``None``     — "pallas" on accelerators; on CPU the fused path
        when eligible (see :func:`resolve_op_ingest_impl`), else tiled.
    """
    if impl is None or impl == "auto":
        # The Pallas kernel relies on TPU sequential-grid semantics
        # (cross steps read buffer rows published by earlier diagonal
        # steps); on every other backend the jnp paths are the safe
        # fast ones.  Auto only picks fused when the caller passed
        # op_index itself (the store layer's batches are affine); the
        # zeros fill below is NOT affine and would corrupt the fused
        # activation transform.
        impl = resolve_op_ingest_impl(
            impl, batch=client.shape[0],
            n_clients=n_clients, n_replicas=n_replicas,
            n_resources=n_resources,
            affine_op_index=(
                op_index is not None
                or (apply_index is None and pend_apply is None)
            ),
        )
    had_op_index = op_index is not None
    if op_index is None and (
        apply_index is not None or pend_apply is not None
    ):
        op_index = jnp.zeros(client.shape, jnp.int32)
    if impl == "fused":
        if None in (n_clients, n_replicas, n_resources):
            raise ValueError(
                "op_ingest impl='fused' needs n_clients/n_replicas/"
                "n_resources"
            )
        if not had_op_index and (
            apply_index is not None or pend_apply is not None
        ):
            raise ValueError(
                "op_ingest impl='fused' with cadence inputs needs a "
                "batch-affine op_index"
            )
        return _oi.op_ingest_fused(
            client, replica, resource, is_write, g0, raw0, floor0,
            n_clients=n_clients, n_replicas=n_replicas,
            n_resources=n_resources,
            op_index=op_index, apply_index=apply_index,
            pend_version=pend_version, pend_resource=pend_resource,
            pend_live=pend_live, pend_apply=pend_apply,
        )
    if impl == "dense":
        return _oi.op_ingest_ref(
            client, replica, resource, is_write, g0, raw0, floor0,
            op_index=op_index, apply_index=apply_index,
            pend_version=pend_version, pend_resource=pend_resource,
            pend_live=pend_live, pend_apply=pend_apply,
        )
    if block is None:
        # Wider strips amortize the scan overhead on CPU; 128 matches
        # the TPU lane width for the Pallas grid.
        block = 256 if impl == "tiled" else 128
    block = max(1, min(block, client.shape[0]))
    packed = _oi.pack_ops(
        client, replica, resource, is_write, g0, raw0, floor0,
        op_index=op_index, apply_index=apply_index,
        pend_version=pend_version, pend_resource=pend_resource,
        pend_live=pend_live, pend_apply=pend_apply, block=block,
    )
    if impl == "tiled":
        return _oi.op_ingest_tiled(packed, block=block)
    if impl == "pallas":
        interpret = _on_cpu() if interpret is None else interpret
        return _oi.op_ingest_pallas(packed, block=block, interpret=interpret)
    raise ValueError(f"unknown op_ingest impl: {impl!r}")


def digest_compare(
    a: jax.Array,  # (..., 4) int32 — side-A digests (SUM, MAX, CHK, CNT)
    b: jax.Array,  # (..., 4) int32 — side-B digests
    *,
    impl: str | None = None,
    block: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Diff two sides' range digests; ``(differ, a_behind, b_behind)``.

    Same contract as ``repro.kernels.ref.digest_compare_ref``
    (bit-exact): bool masks over the leading axes — ``differ`` is the
    stale-range mask the gossip scheduler turns into repair merges.
    Leading axes (e.g. ``(pairs, ranges)``) are flattened into packed
    rows for the tiled paths.  ``impl`` selects the implementation:

      * ``"pallas"`` — the tiled TPU kernel (O(rows·block) memory);
      * ``"tiled"``  — the jnp ``lax.map`` twin of the kernel, the
        fast path on CPU where Pallas runs interpreted;
      * ``"dense"``  — the whole-array oracle;
      * ``None``     — "pallas" on accelerators, "tiled" on CPU.
    """
    if impl is None or impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "tiled"
    if impl == "dense":
        from repro.kernels import ref as kernel_ref

        return kernel_ref.digest_compare_ref(a, b)
    lead = a.shape[:-1]
    a2 = jnp.asarray(a, jnp.int32).reshape(-1, a.shape[-1])
    b2 = jnp.asarray(b, jnp.int32).reshape(-1, b.shape[-1])
    m = a2.shape[0]
    block = max(1, min(block, m))
    packed = _dc.pack_digests(a2, b2, block=block)
    if impl == "tiled":
        out = _dc.digest_compare_tiled(packed, block=block)
    elif impl == "pallas":
        interpret = _on_cpu() if interpret is None else interpret
        out = _dc.digest_compare_pallas(
            packed, block=block, interpret=interpret
        )
    else:
        raise ValueError(f"unknown digest_compare impl: {impl!r}")
    out = out[:m]
    return (
        out[:, _dc.DIFFER].astype(bool).reshape(lead),
        out[:, _dc.A_BEHIND].astype(bool).reshape(lead),
        out[:, _dc.B_BEHIND].astype(bool).reshape(lead),
    )


def histogram(
    values: jax.Array,  # (B,) or (M, B) f32 — observation batches
    *,
    lo,                 # scalar or (M,) — bin range lower bound
    hi,                 # scalar or (M,) — bin range upper bound
    n_bins: int,
    mask: jax.Array | None = None,  # same shape as values; None = all
    impl: str | None = None,
    block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Fixed-bin histograms of observation batches; ``(M, n_bins)``
    int32 counts (``(n_bins,)`` for a 1-D batch).

    Same contract as ``repro.kernels.ref.histogram_ref`` (bit-exact):
    each row bins into ``clip(floor((v - lo) / width), 0, n_bins-1)``
    — out-of-range observations saturate into the edge bins — and
    masked-out observations are not counted.  ``impl`` selects the
    implementation:

      * ``"pallas"`` — the tiled TPU kernel (O(M·(block+n_bins))
        memory, sequential accumulation over column tiles);
      * ``"tiled"``  — the jnp ``lax.map`` twin of the kernel, the
        fast path on CPU where Pallas runs interpreted;
      * ``"dense"``  — the whole-array oracle (the (M, B, n_bins)
        one-hot cube at once);
      * ``None``     — "pallas" on accelerators, "tiled" on CPU.
    """
    if impl is None or impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "tiled"
    one_d = values.ndim == 1
    vals = jnp.atleast_2d(jnp.asarray(values, jnp.float32))
    if mask is not None:
        mask = jnp.atleast_2d(mask)
    params = _hg.metric_params(lo, hi, n_bins)
    if params.shape[0] == 1 and vals.shape[0] > 1:
        params = jnp.broadcast_to(params, (vals.shape[0], 2))
    if impl == "dense":
        from repro.kernels import ref as kernel_ref

        msk = (
            jnp.ones(vals.shape, jnp.int32) if mask is None
            else jnp.asarray(mask, jnp.int32)
        )
        out = kernel_ref.histogram_ref(vals, msk, params, n_bins=n_bins)
    else:
        block = max(1, min(block, vals.shape[1]))
        vals, msk = _hg.pack_observations(vals, mask, block=block)
        if impl == "tiled":
            out = _hg.histogram_tiled(
                vals, msk, params, n_bins=n_bins, block=block
            )
        elif impl == "pallas":
            interpret = _on_cpu() if interpret is None else interpret
            out = _hg.histogram_pallas(
                vals, msk, params, n_bins=n_bins, block=block,
                interpret=interpret,
            )
        else:
            raise ValueError(f"unknown histogram impl: {impl!r}")
    return out[0] if one_d else out


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    layout: str = "bshd",
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """GQA flash attention.

    layout 'bshd': q (B, S, H, hd), k/v (B, T, Hkv, hd) — the model
    substrate's layout; internally transposed to the kernel's (B, H, S,
    hd).  layout 'bhsd': already kernel-native.
    """
    interpret = _on_cpu() if interpret is None else interpret
    if layout == "bshd":
        q = jnp.swapaxes(q, 1, 2)
        k = jnp.swapaxes(k, 1, 2)
        v = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    if layout == "bshd":
        out = jnp.swapaxes(out, 1, 2)
    return out


def audit_duot(duot, *, delta: int = 0, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Run the Pallas audit over a ``repro.core.duot.Duot``.

    Returns the (M, M) packed code matrix (phase | viol<<8 | timed<<9).
    The log is padded to a block multiple with invalid entries."""
    interpret = _on_cpu() if interpret is None else interpret
    m = duot.capacity
    pad = (-m) % block
    def p(x, fill=0):
        if pad == 0:
            return x
        width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, width, constant_values=fill)

    return _va.vclock_audit(
        p(duot.vc),
        p(duot.client, -1),
        p(duot.kind),
        p(duot.resource, -1),
        p(duot.version),
        p(duot.seq),
        p(duot.valid, False),
        delta=delta,
        block=block,
        interpret=interpret,
    )[: m, : m]


def session_admit(
    replica_version: jax.Array,  # (P, R) int32
    read_floor: jax.Array,       # (C, R) int32
    write_floor: jax.Array,      # (C, R) int32
    client: jax.Array,           # (B,) int32
    replica: jax.Array,          # (B,) int32
    resource: jax.Array,         # (B,) int32
    *,
    enforce: bool = True,
    block: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched session-floor admission via the Pallas kernel.

    Same contract as ``repro.kernels.ref.session_admit_ref``: returns
    ``(served, admissible, floor, new_read_floor)``.  The batch is
    padded to a block multiple with invalid rows."""
    interpret = _on_cpu() if interpret is None else interpret
    b = client.shape[0]
    block = max(1, min(block, b))
    pad = (-b) % block

    def p1(x, fill=0):
        return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

    meta = jnp.zeros((b + pad, _sf.META_COLS), jnp.int32)
    meta = meta.at[:, _sf.CLIENT].set(p1(client.astype(jnp.int32)))
    meta = meta.at[:, _sf.REPLICA].set(p1(replica.astype(jnp.int32)))
    meta = meta.at[:, _sf.RESOURCE].set(p1(resource.astype(jnp.int32)))
    meta = meta.at[:, _sf.VALID].set(p1(jnp.ones((b,), jnp.int32)))

    out, new_rf = _sf.session_floor(
        replica_version, read_floor, write_floor, meta,
        enforce=enforce, block=block, interpret=interpret,
    )
    return (
        out[:b, _sf.SERVED],
        out[:b, _sf.ADMISSIBLE].astype(bool),
        out[:b, _sf.FLOOR],
        new_rf,
    )


def policy_score(
    sess: jax.Array,    # (S, SP_COLS) f32 — repro.policy.sla.session_params
    table: jax.Array,   # (LVL_COLS, L) f32 — repro.policy.sla.level_table
    stale: jax.Array,   # (S, L) f32
    viol: jax.Array,    # (S, L) f32
    count: jax.Array,   # (S, L) f32
    *,
    block_s: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched SLA feasibility/utility scoring via the Pallas kernel.

    Same contract as ``repro.kernels.ref.policy_score_ref`` (bit-exact):
    returns ``(utility, feasible)``.  The session axis is padded to a
    block multiple with invalid rows, which score utility 0/feasible 0
    and are stripped before returning.
    """
    interpret = _on_cpu() if interpret is None else interpret
    s = stale.shape[0]
    block_s = max(1, min(block_s, s))
    pad = (-s) % block_s
    if pad:
        sess = jnp.pad(sess, ((0, pad), (0, 0)))  # SP_VALID pads to 0
        stale = jnp.pad(stale, ((0, pad), (0, 0)))
        viol = jnp.pad(viol, ((0, pad), (0, 0)))
        count = jnp.pad(count, ((0, pad), (0, 0)))
    util, feas = _ps.policy_score(
        sess, table, stale, viol, count,
        block_s=block_s, interpret=interpret,
    )
    return util[:s], feas[:s]


def placement_score(
    reads: jax.Array,        # (R, G) f32 — repro.geo.placement.region_demand
    writes: jax.Array,       # (R, G) f32
    read_price: jax.Array,   # (K, G) f32 — repro.geo.placement.candidate_tables
    write_price: jax.Array,  # (K, G) f32
    read_rtt: jax.Array,     # (K, G) f32
    cand_meta: jax.Array,    # (2, K) f32 — [storage cost; validity]
    *,
    max_latency_ms: float,
    impl: str | None = None,
    block_r: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched (resources × candidate-plans) placement scoring.

    Same contract as ``repro.kernels.ref.placement_score_ref``
    (bit-exact): returns ``(utility, feasible)`` over the (R, K) grid.
    ``impl`` selects the implementation:

      * ``"pallas"`` — the tiled TPU kernel;
      * ``"tiled"``  — the jnp ``lax.map`` twin of the kernel, the
        fast path on CPU where Pallas runs interpreted;
      * ``"dense"``  — the reference oracle (whole (R, K) at once);
      * ``None``     — "pallas" on accelerators, "tiled" on CPU.

    The resource axis is padded to a block multiple with zero-demand
    rows, which are stripped before returning.
    """
    if impl is None or impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "tiled"
    if impl == "dense":
        from repro.kernels import ref as kernel_ref

        return kernel_ref.placement_score_ref(
            reads, writes, read_price, write_price, read_rtt, cand_meta,
            max_latency_ms=max_latency_ms,
        )
    r = reads.shape[0]
    block_r = max(1, min(block_r, r))
    pad = (-r) % block_r
    if pad:
        reads = jnp.pad(reads, ((0, pad), (0, 0)))
        writes = jnp.pad(writes, ((0, pad), (0, 0)))
    if impl == "tiled":
        util, feas = _pls.placement_score_tiled(
            reads, writes, read_price, write_price, read_rtt, cand_meta,
            max_latency_ms=max_latency_ms, block_r=block_r,
        )
    elif impl == "pallas":
        interpret = _on_cpu() if interpret is None else interpret
        util, feas = _pls.placement_score(
            reads, writes, read_price, write_price, read_rtt, cand_meta,
            max_latency_ms=max_latency_ms, block_r=block_r,
            interpret=interpret,
        )
    else:
        raise ValueError(f"unknown placement_score impl: {impl!r}")
    return util[:r], feas[:r]


def audit_summary(codes: jax.Array) -> dict[str, jax.Array]:
    """Counts from the packed code matrix."""
    phase = codes & 0xFF
    viol = (codes >> 8) & 1
    timed = (codes >> 9) & 1
    return {
        "n_audited": jnp.sum((phase > 0).astype(jnp.int32)),
        "n_violations": jnp.sum(viol) + jnp.sum(timed),
        "by_phase": jnp.stack(
            [jnp.sum(((phase == c) & (viol > 0)).astype(jnp.int32))
             for c in range(1, 6)]
        ),
    }
