"""Jit'd public wrappers around the Pallas kernels.

On a TPU runtime the kernels compile natively; on CPU (this container,
CI) they run in interpret mode — same code path, Python-executed kernel
body — which is how the correctness sweeps in ``tests/test_kernels.py``
validate them against the ``ref.py`` oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import vclock_audit as _va


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    layout: str = "bshd",
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """GQA flash attention.

    layout 'bshd': q (B, S, H, hd), k/v (B, T, Hkv, hd) — the model
    substrate's layout; internally transposed to the kernel's (B, H, S,
    hd).  layout 'bhsd': already kernel-native.
    """
    interpret = _on_cpu() if interpret is None else interpret
    if layout == "bshd":
        q = jnp.swapaxes(q, 1, 2)
        k = jnp.swapaxes(k, 1, 2)
        v = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    if layout == "bshd":
        out = jnp.swapaxes(out, 1, 2)
    return out


def audit_duot(duot, *, delta: int = 0, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Run the Pallas audit over a ``repro.core.duot.Duot``.

    Returns the (M, M) packed code matrix (phase | viol<<8 | timed<<9).
    The log is padded to a block multiple with invalid entries."""
    interpret = _on_cpu() if interpret is None else interpret
    m = duot.capacity
    pad = (-m) % block
    def p(x, fill=0):
        if pad == 0:
            return x
        width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, width, constant_values=fill)

    return _va.vclock_audit(
        p(duot.vc),
        p(duot.client, -1),
        p(duot.kind),
        p(duot.resource, -1),
        p(duot.version),
        p(duot.seq),
        p(duot.valid, False),
        delta=delta,
        block=block,
        interpret=interpret,
    )[: m, : m]


def audit_summary(codes: jax.Array) -> dict[str, jax.Array]:
    """Counts from the packed code matrix."""
    phase = codes & 0xFF
    viol = (codes >> 8) & 1
    timed = (codes >> 9) & 1
    return {
        "n_audited": jnp.sum((phase > 0).astype(jnp.int32)),
        "n_violations": jnp.sum(viol) + jnp.sum(timed),
        "by_phase": jnp.stack(
            [jnp.sum(((phase == c) & (viol > 0)).astype(jnp.int32))
             for c in range(1, 6)]
        ),
    }
