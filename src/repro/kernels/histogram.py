"""Pallas TPU kernel for device-side metric binning.

The observability plane (``repro.obs``) folds per-epoch observation
batches — staleness ages, read latencies, violation severities, hint
queue depths — into fixed-bin histograms that live in the unified
engine's scan carry, so a whole replay accumulates its distributions
device-side in one jit entry.  The hot shape is ``(M, B)``: M metric
rows (a handful), B observations per row (the epoch batch).  Each row
carries its own bin range as ``(lo, 1/width)`` params; an observation
maps to ``bin = clip(floor((v - lo) / width), 0, n_bins-1)`` — below
``lo`` saturates into bin 0, at-or-above ``hi`` into the top bin — and
masked-out observations contribute nothing.

The binning math lives in one shared tile function (:func:`bin_tile`)
executed identically by the Pallas body and the ``lax.map`` twin
(:func:`histogram_tiled`), and re-derived whole-array by the dense
oracle (``repro.kernels.ref.histogram_ref``).  The bin index is an
elementwise f32 multiply + floor and the counts are integer sums, so
all three implementations are *bit-exact* replicas regardless of tile
walk order (``tests/test_obs.py`` sweeps bin counts, batch sizes, and
empty/saturated bins).

The Pallas grid walks ``B`` in ``block``-column tiles and accumulates
partial counts into one persistent ``(M, n_bins)`` output block
(constant index map, zero-initialised at the first grid step) — O(M ·
(block + n_bins)) memory per step, never the ``(M, B, n_bins)`` one-hot
cube at once.

:func:`hist_percentile` extracts percentiles from the cumulative bins:
for integer-quantised observations (every engine metric — versions,
depths, and RTTs drawn from a fixed matrix binned at unit width) it
reproduces ``jnp.percentile(x, q, method="lower")`` exactly; for
general streams it returns the lower edge of the rank's bin.  An empty
histogram reports ``lo`` (percentile rows must stay finite for the
bench gates).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

# Per-row bin params layout: (M, 2) f32.
LO, INV_W = 0, 1


def bin_tile(
    vals: jax.Array,    # (M, block) f32
    mask: jax.Array,    # (M, block) int32 — 1 = count, 0 = inert
    params: jax.Array,  # (M, 2) f32 — [lo, 1/width] per metric row
    n_bins: int,
) -> jax.Array:
    """Partial counts for one column tile — the one shared
    implementation of the binning math (elementwise f32 index + integer
    sum, so the Pallas kernel, the jnp twin, and the dense oracle agree
    bit-for-bit)."""
    lo = params[:, LO:LO + 1]
    inv_w = params[:, INV_W:INV_W + 1]
    idx = jnp.floor((vals - lo) * inv_w).astype(jnp.int32)
    idx = jnp.clip(idx, 0, n_bins - 1)
    sel = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_bins), 2)
    hit = (idx[:, :, None] == sel) & (mask[:, :, None] > 0)
    return jnp.sum(hit.astype(jnp.int32), axis=1)


def metric_params(lo, hi, n_bins: int) -> jax.Array:
    """Pack per-row ``[lo, 1/width]`` bin params; ``lo``/``hi`` scalars
    or ``(M,)`` arrays (broadcast against each other)."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    lo, hi = jnp.broadcast_arrays(jnp.atleast_1d(lo), jnp.atleast_1d(hi))
    inv_w = jnp.float32(n_bins) / (hi - lo)
    return jnp.stack([lo, inv_w], axis=1)


def pack_observations(
    vals: jax.Array,           # (M, B) f32
    mask: jax.Array | None,    # (M, B) — 0/1; None counts everything
    *,
    block: int,
) -> tuple[jax.Array, jax.Array]:
    """Pad the observation axis to a ``block`` multiple with inert
    (mask=0) columns; returns ``(vals, mask)`` as f32/int32."""
    m, b = vals.shape
    vals = jnp.asarray(vals, jnp.float32)
    if mask is None:
        mask = jnp.ones((m, b), jnp.int32)
    else:
        mask = jnp.asarray(mask, jnp.int32)
    pad = (-b) % block
    if pad:
        vals = jnp.pad(vals, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    return vals, mask


def _histogram_kernel(n_bins, val_ref, mask_ref, par_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += bin_tile(
        val_ref[...], mask_ref[...], par_ref[...], n_bins
    )


def histogram_pallas(
    vals: jax.Array,    # (M, B') f32, B' a multiple of block
    mask: jax.Array,    # (M, B') int32
    params: jax.Array,  # (M, 2) f32
    *,
    n_bins: int,
    block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tiled binning via ``pallas_call``; returns ``(M, n_bins)`` int32
    counts.  The grid is sequential ("arbitrary") because every column
    tile accumulates into the same persistent output block."""
    m, b = vals.shape
    block = min(block, b)
    assert b % block == 0, f"B={b} must be a multiple of block={block}"
    nb = b // block
    return pl.pallas_call(
        functools.partial(_histogram_kernel, n_bins),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((m, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_bins), jnp.int32),
        compiler_params=CompilerParams(
            # Column tiles revisit the same output block; the grid must
            # run in order.
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(vals, mask, params)


def histogram_tiled(
    vals: jax.Array,
    mask: jax.Array,
    params: jax.Array,
    *,
    n_bins: int,
    block: int = 128,
) -> jax.Array:
    """jnp twin of the Pallas kernel: same tile walk, ``lax.map`` grid.

    The CPU fast path (Pallas runs interpreted there) — O(M · block)
    observations live per step, and bit-exact with the kernel because
    every tile runs the identical :func:`bin_tile` and integer count
    addition is order-free."""
    m, b = vals.shape
    block = min(block, b)
    assert b % block == 0, f"B={b} must be a multiple of block={block}"
    nb = b // block
    tiles = (
        vals.reshape(m, nb, block).swapaxes(0, 1),
        mask.reshape(m, nb, block).swapaxes(0, 1),
    )
    parts = jax.lax.map(
        lambda t: bin_tile(t[0], t[1], params, n_bins), tiles
    )
    return jnp.sum(parts, axis=0, dtype=jnp.int32)


def hist_edges(lo: float, hi: float, n_bins: int) -> jax.Array:
    """The ``n_bins + 1`` bin edges of one metric row."""
    return jnp.linspace(lo, hi, n_bins + 1, dtype=jnp.float32)


def hist_percentile(
    hist: jax.Array,  # (..., n_bins) int32 counts
    lo,               # scalar or (...,) — bin range lower bound
    width,            # scalar or (...,) — bin width
    q: float,
) -> jax.Array:
    """The q-th percentile's bin lower edge from cumulative counts.

    Rank semantics match ``jnp.percentile(x, q, method="lower")``:
    ``rank = floor(q/100 · (n-1))`` and the answer is the bin holding
    the rank-th sorted observation — exact when observations are
    quantised to bin lower edges, the lower-edge approximation
    otherwise.  Empty histograms report ``lo`` so downstream gates stay
    finite."""
    hist = jnp.asarray(hist, jnp.int32)
    n = jnp.sum(hist, axis=-1)
    rank = jnp.floor(
        jnp.float32(q) / 100.0
        * jnp.maximum(n - 1, 0).astype(jnp.float32)
    ).astype(jnp.int32)
    cum = jnp.cumsum(hist, axis=-1)
    idx = jnp.sum((cum <= rank[..., None]).astype(jnp.int32), axis=-1)
    idx = jnp.where(n > 0, jnp.minimum(idx, hist.shape[-1] - 1), 0)
    lo = jnp.asarray(lo, jnp.float32)
    width = jnp.asarray(width, jnp.float32)
    return lo + idx.astype(jnp.float32) * width
