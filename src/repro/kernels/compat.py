"""Version compatibility for the Pallas TPU API surface."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases;
# support both so the kernels run on either side of the rename.
CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version"
    )
