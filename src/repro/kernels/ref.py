"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its semantics defined HERE; the Pallas
implementations must match these to float tolerance (tests sweep shapes
and dtypes with ``assert_allclose``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -2.0 ** 30


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    softmax_scale: float | None = None,
) -> Array:
    """Reference attention.

    q: (B, H, S, hd); k/v: (B, Hkv, T, hd) with H % Hkv == 0.
    Returns (B, H, S, hd), computed in f32, cast back to q.dtype.
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = (hd ** -0.5) if softmax_scale is None else softmax_scale

    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) * scale
    t = k.shape[2]
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", weights, vf)
    return out.reshape(b, h, s, hd).astype(q.dtype)


def session_admit_ref(
    replica_version: Array,  # (P, R) int32
    read_floor: Array,       # (C, R) int32
    write_floor: Array,      # (C, R) int32
    client: Array,           # (B,) int32
    replica: Array,          # (B,) int32
    resource: Array,         # (B,) int32
    *,
    enforce: bool = True,
    valid: Array | None = None,  # (B,) bool
) -> tuple[Array, Array, Array, Array]:
    """Reference batched X-STCC admission check + floor update.

    The serving-path hot loop: for each op, ``replica_version[p, r] >=
    max(read_floor[c, r], write_floor[c, r])`` decides admissibility;
    under session enforcement the served version is lifted to the floor
    (the admissible-replica reroute); the read floors then absorb the
    served versions.  The batch is checked against the *pre-batch*
    floors (concurrent admission — router semantics).

    Returns ``(served, admissible, floor, new_read_floor)``.
    """
    c = jnp.asarray(client, jnp.int32)
    p = jnp.asarray(replica, jnp.int32)
    r = jnp.asarray(resource, jnp.int32)
    ok = jnp.ones(c.shape, bool) if valid is None else jnp.asarray(valid, bool)

    raw = replica_version[p, r]
    floor = jnp.maximum(read_floor[c, r], write_floor[c, r])
    admissible = jnp.logical_and(ok, raw >= floor)
    served = jnp.maximum(raw, floor) if enforce else raw
    served = jnp.where(ok, served, 0)
    new_rf = read_floor.at[c, r].max(served)
    return served, admissible, jnp.where(ok, floor, 0), new_rf


def vclock_audit_ref(
    vc: Array,        # (M, N) int32 vector clocks
    client: Array,    # (M,) int32
    kind: Array,      # (M,) int32 (0=read, 1=write)
    resource: Array,  # (M,) int32
    version: Array,   # (M,) int32
    seq: Array,       # (M,) int32 arrival timestamps
    valid: Array,     # (M,) bool
    *,
    delta: int = 0,
) -> Array:
    """Reference pairwise audit (paper eq. 1a-1d + timed bound).

    Returns (M, M) int32 codes: ``phase | violation << 8 | timed << 9``
    where phase follows repro.core.audit.PHASE_* (0..6).
    """
    m = vc.shape[0]
    a = vc[:, None, :]
    b_ = vc[None, :, :]
    le = jnp.all(a <= b_, axis=-1)
    lt = jnp.any(a < b_, axis=-1)
    hb = jnp.logical_and(le, lt)

    pair_valid = valid[:, None] & valid[None, :]
    same_res = resource[:, None] == resource[None, :]
    ordered = seq[:, None] < seq[None, :]
    same_client = client[:, None] == client[None, :]
    base = pair_valid & same_res & ordered
    ki = kind[:, None]
    kj = kind[None, :]
    vi = version[:, None]
    vj = version[None, :]

    phase = jnp.zeros((m, m), jnp.int32)
    sc = base & same_client & hb
    phase = jnp.where(sc & (ki == 0) & (kj == 0), 1, phase)   # a1 MR
    phase = jnp.where(sc & (ki == 1) & (kj == 1), 2, phase)   # a2 MW
    phase = jnp.where(sc & (ki == 1) & (kj == 0), 3, phase)   # a3 RYW
    phase = jnp.where(sc & (ki == 0) & (kj == 1), 4, phase)   # a4 WFR
    phase = jnp.where(base & ~same_client & hb, 5, phase)     # b1 TCC
    phase = jnp.where(base & ~hb, 6, phase)                   # b2 conc

    viol = jnp.zeros((m, m), bool)
    viol |= (phase == 1) & (vj < vi)
    viol |= (phase == 2) & (vj <= vi)
    viol |= (phase == 3) & (vj < vi)
    viol |= (phase == 4) & (vj <= vi)
    viol |= (phase == 5) & (ki == 1) & (kj == 0) & (vj < vi)

    gap = seq[None, :] - seq[:, None]
    timed = (
        (delta > 0) & base & (ki == 1) & (kj == 0) & (gap > delta) & (vj < vi)
    )
    return phase | (viol.astype(jnp.int32) << 8) | (timed.astype(jnp.int32) << 9)
