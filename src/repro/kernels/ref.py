"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its semantics defined HERE; the Pallas
implementations must match these to float tolerance (tests sweep shapes
and dtypes with ``assert_allclose``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -2.0 ** 30


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    softmax_scale: float | None = None,
) -> Array:
    """Reference attention.

    q: (B, H, S, hd); k/v: (B, Hkv, T, hd) with H % Hkv == 0.
    Returns (B, H, S, hd), computed in f32, cast back to q.dtype.
    """
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = (hd ** -0.5) if softmax_scale is None else softmax_scale

    qg = q.reshape(b, hkv, g, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) * scale
    t = k.shape[2]
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", weights, vf)
    return out.reshape(b, h, s, hd).astype(q.dtype)


# Cadence sentinel: an apply index no op index ever reaches ("never
# visible").  Shared with repro.core.replicated_store's stream scheduler.
NEVER = 2 ** 30


def op_ingest_ref(
    client: Array,      # (B,) int32
    replica: Array,     # (B,) int32
    resource: Array,    # (B,) int32
    is_write: Array,    # (B,) bool
    g0: Array,          # (B,) int32 — global_version[resource] per op
    raw0: Array,        # (B,) int32 — replica_version[replica, resource]
    floor0: Array,      # (B,) int32 — max(read_floor, write_floor)[c, r]
    *,
    op_index: Array | None = None,     # (B,) int32 — global op index g
    apply_index: Array | None = None,  # (B,) int32 — emulated apply point a
    pend_version: Array | None = None,   # (Q,) int32
    pend_resource: Array | None = None,  # (Q,) int32
    pend_live: Array | None = None,      # (Q,) bool
    pend_apply: Array | None = None,     # (Q,) int32 — apply point per slot
) -> tuple[Array, Array, Array]:
    """Reference batched op-ingestion prefixes (dense O(B²) masks).

    The semantic core of ``repro.core.xstcc.apply_op_batch``: for every
    op ``i`` of a ``(B,)`` batch, reduce over the ops ``j < i`` that
    precede it —

      * ``occ[i]``   — per-resource prefix write count (version rank);
      * ``raw[i]``   — replica-visible version: the gathered
        ``replica_version`` joined with every *visible* prior batch
        write and every visible pending-ring write;
      * ``floor[i]`` — session floor: the initial MR/RYW floor joined
        with the per-(client, resource) prefix max of prior
        contributions (write versions / raw read versions).

    Visibility is the closed-form cadence predicate

      ``visible(i, j) = is_write(j) ∧ same_resource ∧
                        (coordinator(i) == coordinator(j)
                         ∨ op_index(i) >= apply_index(j))``

    which covers all three merge cadences of the store layer: scalar
    semantics (``apply_index=None`` — coordinator-only), merge-every-op
    (``apply_index == 0``), and the op-index cadence / timed-Δ schedule
    (``apply_index`` = the stream scheduler's emulated apply points,
    ``NEVER`` for reads).  Pending-ring visibility is the same predicate
    against the ``(Q,)`` slot vectors — no ``(B, B)`` or ``(B, Q)``
    matrices cross the API.

    This oracle *does* materialize the dense masks; the Pallas kernel
    (``repro.kernels.op_ingest``) and its jnp tiled twin compute the
    same reduction in ``(Bi, Bj)`` blocks with O(B·tile) memory and must
    match bit-exactly.
    """
    c = jnp.asarray(client, jnp.int32)
    p = jnp.asarray(replica, jnp.int32)
    r = jnp.asarray(resource, jnp.int32)
    is_w = jnp.asarray(is_write, bool)
    b = c.shape[0]

    idx = jnp.arange(b, dtype=jnp.int32)
    lower = idx[:, None] > idx[None, :]
    same_r = r[:, None] == r[None, :]
    prior_w = lower & same_r & is_w[None, :]

    occ = jnp.sum(prior_w, axis=1, dtype=jnp.int32)
    ver_w = jnp.asarray(g0, jnp.int32) + occ + 1
    verw_masked = jnp.where(is_w, ver_w, 0)

    vis = prior_w & (p[:, None] == p[None, :])
    if apply_index is not None:
        g = jnp.asarray(op_index, jnp.int32)
        a = jnp.asarray(apply_index, jnp.int32)
        vis = vis | (prior_w & (g[:, None] >= a[None, :]))
    raw = jnp.maximum(
        jnp.asarray(raw0, jnp.int32),
        jnp.max(jnp.where(vis, verw_masked[None, :], 0), axis=1),
    )
    if pend_apply is not None:
        g = jnp.asarray(op_index, jnp.int32)
        pvis = (
            jnp.asarray(pend_live, bool)[None, :]
            & (r[:, None] == jnp.asarray(pend_resource, jnp.int32)[None, :])
            & (g[:, None] >= jnp.asarray(pend_apply, jnp.int32)[None, :])
        )
        raw = jnp.maximum(
            raw,
            jnp.max(
                jnp.where(
                    pvis, jnp.asarray(pend_version, jnp.int32)[None, :], 0
                ),
                axis=1,
            ),
        )

    same_cr = (c[:, None] == c[None, :]) & same_r
    contrib = jnp.where(is_w, ver_w, raw)
    floor = jnp.maximum(
        jnp.asarray(floor0, jnp.int32),
        jnp.max(jnp.where(lower & same_cr, contrib[None, :], 0), axis=1),
    )
    return occ, raw, floor


def session_admit_ref(
    replica_version: Array,  # (P, R) int32
    read_floor: Array,       # (C, R) int32
    write_floor: Array,      # (C, R) int32
    client: Array,           # (B,) int32
    replica: Array,          # (B,) int32
    resource: Array,         # (B,) int32
    *,
    enforce: bool = True,
    valid: Array | None = None,  # (B,) bool
) -> tuple[Array, Array, Array, Array]:
    """Reference batched X-STCC admission check + floor update.

    The serving-path hot loop: for each op, ``replica_version[p, r] >=
    max(read_floor[c, r], write_floor[c, r])`` decides admissibility;
    under session enforcement the served version is lifted to the floor
    (the admissible-replica reroute); the read floors then absorb the
    served versions.  The batch is checked against the *pre-batch*
    floors (concurrent admission — router semantics).

    Returns ``(served, admissible, floor, new_read_floor)``.
    """
    c = jnp.asarray(client, jnp.int32)
    p = jnp.asarray(replica, jnp.int32)
    r = jnp.asarray(resource, jnp.int32)
    ok = jnp.ones(c.shape, bool) if valid is None else jnp.asarray(valid, bool)

    raw = replica_version[p, r]
    floor = jnp.maximum(read_floor[c, r], write_floor[c, r])
    admissible = jnp.logical_and(ok, raw >= floor)
    served = jnp.maximum(raw, floor) if enforce else raw
    served = jnp.where(ok, served, 0)
    new_rf = read_floor.at[c, r].max(served)
    return served, admissible, jnp.where(ok, floor, 0), new_rf


# Policy-scorer constants (shared with the Pallas kernel, re-exported
# by repro.policy.sla): utility penalty weight on the SLA-excess term —
# far above any per-op dollar cost, so argmax(utility) prefers any
# feasible level over every infeasible one — and the weight of the
# structural (latency / data-age) bounds, which are violated on *every*
# request and so outweigh relative rate overshoots.
INFEASIBLE_PENALTY = 1.0e6
STRUCTURAL_WEIGHT = 10.0

# Packed-array layouts of the policy scorer.  Defined HERE (with the
# scoring semantics) and imported by repro.policy.sla (the packers) and
# kernels.policy_score (the Pallas kernel), so layout and use can never
# drift apart.  Session-parameter columns of the (S, SP_COLS) array:
SP_READ_FRAC, SP_MAX_STALE, SP_MAX_VIOL, SP_MAX_LAT, SP_MAX_AGE, SP_VALID = (
    0, 1, 2, 3, 4, 5,
)
SP_COLS = 8
# Level-table rows of the (LVL_COLS, L) array:
LVL_READ_COST, LVL_WRITE_COST, LVL_REPAIR_COST, LVL_READ_LAT, LVL_STALE_AGE = (
    0, 1, 2, 3, 4,
)
LVL_COLS = 8


def policy_score_ref(
    sess: Array,   # (S, SP_COLS) f32 — packed session params (policy.sla)
    table: Array,  # (LVL_COLS, L) f32 — packed analytic level table
    stale: Array,  # (S, L) f32 — windowed stale-read rate
    viol: Array,   # (S, L) f32 — windowed violation rate
    count: Array,  # (S, L) f32 — telemetry samples (0 = unobserved)
) -> tuple[Array, Array]:
    """Reference (sessions × levels) SLA feasibility / utility scorer.

    Column/row layouts are defined in ``repro.policy.sla`` (SP_* and
    LVL_* indices).  Per cell:

      * telemetry with no samples is treated optimistically (rate 0 —
        the level is presumed feasible until observed otherwise, which
        makes a greedy controller explore cheapest-first);
      * ``cost = rf*(read_cost + stale*repair) + (1-rf)*write_cost`` —
        the analytic $/op, with observed staleness feeding the repair
        term;
      * the SLA *excess* grades how badly the four bounds (stale rate,
        violation rate, read latency, data age) are broken — relative
        overshoot for the measured rates, 0/1 for the structural
        latency/age bounds; feasibility is excess == 0;
      * ``utility = -cost - PENALTY*excess`` so argmax picks the
        cheapest feasible level, and when *nothing* is feasible (e.g. a
        write storm under a strict SLA) degrades to the least-violating
        level rather than the cheapest-and-worst one.

    Invalid session rows (``SP_VALID == 0``) score utility 0, feasible 0.
    The Pallas kernel (``repro.kernels.policy_score``) must reproduce
    this bit-exactly under jit — same op order, same dtypes.
    """
    sess = jnp.asarray(sess, jnp.float32)
    table = jnp.asarray(table, jnp.float32)
    stale = jnp.asarray(stale, jnp.float32)
    viol = jnp.asarray(viol, jnp.float32)
    count = jnp.asarray(count, jnp.float32)

    col = lambda i: sess[:, i:i + 1]          # noqa: E731
    rf = col(SP_READ_FRAC)
    max_stale = col(SP_MAX_STALE)
    max_viol = col(SP_MAX_VIOL)
    max_lat = col(SP_MAX_LAT)
    max_age = col(SP_MAX_AGE)
    valid = col(SP_VALID) > 0.0

    read_cost = table[LVL_READ_COST][None, :]
    write_cost = table[LVL_WRITE_COST][None, :]
    repair = table[LVL_REPAIR_COST][None, :]
    lat = table[LVL_READ_LAT][None, :]
    age = table[LVL_STALE_AGE][None, :]

    has = count > 0.0
    s_e = jnp.where(has, stale, 0.0)
    v_e = jnp.where(has, viol, 0.0)
    cost = rf * (read_cost + s_e * repair) + (1.0 - rf) * write_cost
    eps = jnp.float32(1.0e-6)
    structural = jnp.float32(STRUCTURAL_WEIGHT)
    excess = (
        jnp.maximum(s_e - max_stale, 0.0) / jnp.maximum(max_stale, eps)
        + jnp.maximum(v_e - max_viol, 0.0) / jnp.maximum(max_viol, eps)
        + structural * (lat > max_lat).astype(jnp.float32)
        + structural * (age > max_age).astype(jnp.float32)
    )
    feas = (excess == 0.0) & valid
    utility = jnp.where(
        valid, -cost - jnp.float32(INFEASIBLE_PENALTY) * excess, 0.0
    )
    return utility, feas.astype(jnp.int32)


def placement_score_ref(
    reads: Array,        # (R, G) f32 — reads per resource per client region
    writes: Array,       # (R, G) f32 — writes per resource per client region
    read_price: Array,   # (K, G) f32 — $/read issued from region g, plan k
    write_price: Array,  # (K, G) f32 — $/write issued from region g, plan k
    read_rtt: Array,     # (K, G) f32 — read latency ms from region g, plan k
    cand_meta: Array,    # (2, K) f32 — row 0: $/resource storage+base cost;
                         #              row 1: candidate validity (1.0/0.0)
    *,
    max_latency_ms: float,
) -> tuple[Array, Array]:
    """Reference (resources × candidate-plans) placement scorer.

    The geo twin of :func:`policy_score_ref`: for every resource ``r``
    and candidate placement ``k`` (a replication-factor ×
    region-assignment choice, pre-digested by
    ``repro.geo.placement.candidate_tables`` into per-region price and
    latency rows),

      * ``cost = store[k] + Σ_g reads[r,g]·read_price[k,g]
                          + writes[r,g]·write_price[k,g]`` — the
        analytic eq. 5-8 bill of serving resource ``r``'s regional
        demand under plan ``k``;
      * the SLA excess counts, per region *with demand*, a structural
        violation when the plan's read latency from that region exceeds
        ``max_latency_ms``; invalid candidate rows add one structural
        violation so they rank below every valid plan;
      * ``feasible = excess == 0``; ``utility = -cost - PENALTY·excess``
        so argmax picks the cheapest SLA-feasible plan and degrades to
        the least-violating one when none is feasible.

    The region axis is reduced with an unrolled fixed-order loop —
    ``G`` is tiny and static — so the Pallas kernel
    (``repro.kernels.placement_score``) and its tiled jnp twin
    reproduce this *bit-exactly* under jit (same op order, same
    dtypes); ``tests/test_geo.py`` sweeps all three.
    """
    reads = jnp.asarray(reads, jnp.float32)
    writes = jnp.asarray(writes, jnp.float32)
    read_price = jnp.asarray(read_price, jnp.float32)
    write_price = jnp.asarray(write_price, jnp.float32)
    read_rtt = jnp.asarray(read_rtt, jnp.float32)
    cand_meta = jnp.asarray(cand_meta, jnp.float32)

    r, g = reads.shape
    k = read_price.shape[0]
    store = cand_meta[0][None, :]                    # (1, K)
    valid = cand_meta[1][None, :] > 0.0              # (1, K)
    max_lat = jnp.float32(max_latency_ms)
    structural = jnp.float32(STRUCTURAL_WEIGHT)

    cost = jnp.broadcast_to(store, (r, k))
    excess = jnp.zeros((r, k), jnp.float32)
    for gi in range(g):                              # static, fixed order
        cost = cost + reads[:, gi:gi + 1] * read_price[None, :, gi]
        cost = cost + writes[:, gi:gi + 1] * write_price[None, :, gi]
        demand = (reads[:, gi:gi + 1] + writes[:, gi:gi + 1]) > 0.0
        late = read_rtt[None, :, gi] > max_lat
        excess = excess + structural * jnp.logical_and(
            demand, late
        ).astype(jnp.float32)
    excess = excess + structural * jnp.logical_not(valid).astype(jnp.float32)
    feas = excess == 0.0
    utility = -cost - jnp.float32(INFEASIBLE_PENALTY) * excess
    return utility, feas.astype(jnp.int32)


def vclock_audit_ref(
    vc: Array,        # (M, N) int32 vector clocks
    client: Array,    # (M,) int32
    kind: Array,      # (M,) int32 (0=read, 1=write)
    resource: Array,  # (M,) int32
    version: Array,   # (M,) int32
    seq: Array,       # (M,) int32 arrival timestamps
    valid: Array,     # (M,) bool
    *,
    delta: int = 0,
) -> Array:
    """Reference pairwise audit (paper eq. 1a-1d + timed bound).

    Returns (M, M) int32 codes: ``phase | violation << 8 | timed << 9``
    where phase follows repro.core.audit.PHASE_* (0..6).
    """
    m = vc.shape[0]
    a = vc[:, None, :]
    b_ = vc[None, :, :]
    le = jnp.all(a <= b_, axis=-1)
    lt = jnp.any(a < b_, axis=-1)
    hb = jnp.logical_and(le, lt)

    pair_valid = valid[:, None] & valid[None, :]
    same_res = resource[:, None] == resource[None, :]
    ordered = seq[:, None] < seq[None, :]
    same_client = client[:, None] == client[None, :]
    base = pair_valid & same_res & ordered
    ki = kind[:, None]
    kj = kind[None, :]
    vi = version[:, None]
    vj = version[None, :]

    phase = jnp.zeros((m, m), jnp.int32)
    sc = base & same_client & hb
    phase = jnp.where(sc & (ki == 0) & (kj == 0), 1, phase)   # a1 MR
    phase = jnp.where(sc & (ki == 1) & (kj == 1), 2, phase)   # a2 MW
    phase = jnp.where(sc & (ki == 1) & (kj == 0), 3, phase)   # a3 RYW
    phase = jnp.where(sc & (ki == 0) & (kj == 1), 4, phase)   # a4 WFR
    phase = jnp.where(base & ~same_client & hb, 5, phase)     # b1 TCC
    phase = jnp.where(base & ~hb, 6, phase)                   # b2 conc

    viol = jnp.zeros((m, m), bool)
    viol |= (phase == 1) & (vj < vi)
    viol |= (phase == 2) & (vj <= vi)
    viol |= (phase == 3) & (vj < vi)
    viol |= (phase == 4) & (vj <= vi)
    viol |= (phase == 5) & (ki == 1) & (kj == 0) & (vj < vi)

    gap = seq[None, :] - seq[:, None]
    timed = (
        (delta > 0) & base & (ki == 1) & (kj == 0) & (gap > delta) & (vj < vi)
    )
    return phase | (viol.astype(jnp.int32) << 8) | (timed.astype(jnp.int32) << 9)


def digest_compare_ref(
    a: Array,  # (M, 4) int32 — side-A digest components (SUM, MAX, CHK, CNT)
    b: Array,  # (M, 4) int32 — side-B digest components
) -> tuple[Array, Array, Array]:
    """Dense oracle of the gossip digest compare.

    Whole-array re-derivation of ``kernels.digest_compare.compare_tile``
    over unpacked component rows: returns ``(differ, a_behind,
    b_behind)`` bool ``(M,)`` masks.  ``differ`` is the stale-range
    mask (any component disagrees); the behind flags order the sides by
    (MAX, then SUM), with a full tie that still differs (CHK/CNT
    disagree) marking *both* sides — divergence within the range.
    Integer-only math, bit-exact with the Pallas kernel and its jnp
    twin (``tests/test_gossip.py``).
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    d = a - b
    differ = jnp.any(d != 0, axis=-1)
    d_sum, d_max = d[..., 0], d[..., 1]
    tie = (d_max == 0) & (d_sum == 0)
    a_behind = differ & (
        (d_max < 0) | ((d_max == 0) & (d_sum < 0)) | tie
    )
    b_behind = differ & (
        (d_max > 0) | ((d_max == 0) & (d_sum > 0)) | tie
    )
    return differ, a_behind, b_behind


def histogram_ref(
    vals: Array,    # (M, B) f32 — observation batches, one row per metric
    mask: Array,    # (M, B) int32 — 1 = count, 0 = inert
    params: Array,  # (M, 2) f32 — [lo, 1/width] per metric row
    *,
    n_bins: int,
) -> Array:
    """Dense oracle of the metric-binning kernel.

    Whole-array re-derivation of ``kernels.histogram.bin_tile``: bin
    index ``clip(floor((v - lo) / width), 0, n_bins-1)`` (below-range
    saturates into bin 0, at-or-above ``hi`` into the top bin), masked
    one-hot counts summed over the observation axis — the full
    ``(M, B, n_bins)`` cube the tiled paths never materialize.  The
    index is the same elementwise f32 multiply + floor and the counts
    are integer sums, so the oracle is bit-exact with the Pallas kernel
    and its jnp twin (``tests/test_obs.py``).
    """
    vals = jnp.asarray(vals, jnp.float32)
    mask = jnp.asarray(mask, jnp.int32)
    params = jnp.asarray(params, jnp.float32)
    lo = params[:, 0:1]
    inv_w = params[:, 1:2]
    idx = jnp.clip(
        jnp.floor((vals - lo) * inv_w).astype(jnp.int32), 0, n_bins - 1
    )
    sel = jnp.arange(n_bins, dtype=jnp.int32)[None, None, :]
    hit = (idx[:, :, None] == sel) & (mask[:, :, None] > 0)
    return jnp.sum(hit.astype(jnp.int32), axis=1)
