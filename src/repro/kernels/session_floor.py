"""Pallas TPU kernel for the batched X-STCC session-floor admission check.

This is the serving-scale per-op hot loop (paper §3.4 client side): for
every op of a ``(B,)`` batch, gather the replica's applied version and
the session's MR/RYW floor, decide admissibility
(``replica_version[p, r] >= max(read_floor, write_floor)``), lift the
served version to the floor under session enforcement, and scatter-max
the served versions back into the read floors.

TPU mapping: the gathers/scatters are irregular, so the kernel turns
them into dense one-hot contractions — MXU/VPU-friendly, no
gather/scatter primitives:

  * gather ``rv[p_i, r_i]``  ->  ``sum((onehot_p @ rv) * onehot_r, -1)``
  * scatter-max into floors  ->  ``max_b(onehot_c ⊗ onehot_r * served)``

The grid tiles the batch; each tile accumulates its partial floor
update into the (C, R) output across sequentially-executed grid steps
("arbitrary" dimension semantics), exactly the flash-attention
accumulator pattern.  int32 versions are exact in f32 up to 2^24 —
far above any snapshot version the engine produces.

Semantics are defined by ``repro.kernels.ref.session_admit_ref``; the
sweeps in ``tests/test_replicated_store.py`` check agreement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

# ops meta columns
CLIENT, REPLICA, RESOURCE, VALID = 0, 1, 2, 3
META_COLS = 8
# out columns
SERVED, ADMISSIBLE, FLOOR, RAW = 0, 1, 2, 3
OUT_COLS = 8


def _session_floor_kernel(
    rv_ref, rf_ref, wf_ref, ops_ref, out_ref, newrf_ref,
    *, n_replicas: int, n_clients: int, n_resources: int, enforce: bool,
):
    ops = ops_ref[...]                       # (bm, META_COLS)
    bm = ops.shape[0]
    c = ops[:, CLIENT]
    p = ops[:, REPLICA]
    r = ops[:, RESOURCE]
    ok = ops[:, VALID] > 0

    rv = rv_ref[...].astype(jnp.float32)     # (P, R)
    rf = rf_ref[...].astype(jnp.float32)     # (C, R)
    wf = wf_ref[...].astype(jnp.float32)     # (C, R)

    iota = functools.partial(jax.lax.broadcasted_iota, jnp.int32)
    oh_p = (p[:, None] == iota((bm, n_replicas), 1)).astype(jnp.float32)
    oh_c = (c[:, None] == iota((bm, n_clients), 1)).astype(jnp.float32)
    oh_r = (r[:, None] == iota((bm, n_resources), 1)).astype(jnp.float32)

    # One-hot gathers (exact for int32 versions < 2^24).
    raw = jnp.sum(jnp.dot(oh_p, rv) * oh_r, axis=-1)
    fl = jnp.maximum(
        jnp.sum(jnp.dot(oh_c, rf) * oh_r, axis=-1),
        jnp.sum(jnp.dot(oh_c, wf) * oh_r, axis=-1),
    )
    adm = jnp.logical_and(ok, raw >= fl)
    served = jnp.maximum(raw, fl) if enforce else raw
    served = jnp.where(ok, served, 0.0)

    out = jnp.zeros((bm, OUT_COLS), jnp.int32)
    out = out.at[:, SERVED].set(served.astype(jnp.int32))
    out = out.at[:, ADMISSIBLE].set(adm.astype(jnp.int32))
    out = out.at[:, FLOOR].set(jnp.where(ok, fl, 0.0).astype(jnp.int32))
    out = out.at[:, RAW].set(jnp.where(ok, raw, 0.0).astype(jnp.int32))
    out_ref[...] = out

    # Scatter-max of served versions into the read floors: dense
    # (bm, C, R) one-hot product reduced over the batch tile, then
    # max-accumulated into the (C, R) output across grid steps.
    upd = jnp.max(
        oh_c[:, :, None] * oh_r[:, None, :] * served[:, None, None],
        axis=0,
    ).astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        newrf_ref[...] = jnp.maximum(rf_ref[...], upd)

    @pl.when(pl.program_id(0) > 0)
    def _accum():
        newrf_ref[...] = jnp.maximum(newrf_ref[...], upd)


def session_floor(
    replica_version: jax.Array,  # (P, R) int32
    read_floor: jax.Array,       # (C, R) int32
    write_floor: jax.Array,      # (C, R) int32
    ops_meta: jax.Array,         # (B, META_COLS) int32
    *,
    enforce: bool = True,
    block: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Tiled batched admission check.

    Returns ``(out, new_read_floor)`` where ``out`` is ``(B, OUT_COLS)``
    int32 (columns SERVED / ADMISSIBLE / FLOOR / RAW) and
    ``new_read_floor`` is the (C, R) floor table after the batch.
    ``B`` must be a multiple of ``block`` (pad with VALID=0 rows).
    """
    b = ops_meta.shape[0]
    n_replicas, n_resources = replica_version.shape
    n_clients = read_floor.shape[0]
    block = min(block, b)
    assert b % block == 0, f"B={b} must be a multiple of block={block}"
    nb = b // block

    kernel = functools.partial(
        _session_floor_kernel,
        n_replicas=n_replicas, n_clients=n_clients,
        n_resources=n_resources, enforce=enforce,
    )
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n_replicas, n_resources), lambda i: (0, 0)),
            pl.BlockSpec((n_clients, n_resources), lambda i: (0, 0)),
            pl.BlockSpec((n_clients, n_resources), lambda i: (0, 0)),
            pl.BlockSpec((block, META_COLS), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, OUT_COLS), lambda i: (i, 0)),
            pl.BlockSpec((n_clients, n_resources), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, OUT_COLS), jnp.int32),
            jax.ShapeDtypeStruct((n_clients, n_resources), jnp.int32),
        ],
        compiler_params=CompilerParams(
            # The floor accumulator carries across grid steps.
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(replica_version, read_floor, write_floor, ops_meta)
