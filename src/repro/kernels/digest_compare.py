"""Pallas TPU kernel for the gossip digest-compare pass.

The gossip anti-entropy subsystem (``repro.gossip``) summarizes each
replica's ``(P, R)`` applied-version table into per-resource-range
digests — ``(K, 4)`` int32 components per replica (wrapping SUM, MAX,
weighted CHK, nonzero CNT; see ``repro.gossip.digest``) — and a digest
exchange diffs two replicas' summaries to find the stale ranges worth
repairing.  At fleet scale (every gossip round compares every scheduled
peer pair over every range) this is a dense elementwise VPU workload
over packed ``(pairs · ranges)`` rows: the same shape as
``kernels/placement_score``, so the kernel tiles the row axis and each
grid step loads one ``(block, DIG_COLS)`` slab of paired digest
components and writes the ``(block, OUT_COLS)`` verdict tile — O(rows ·
block) memory, never the dense (pairs, ranges, components) cube at
once.

The verdict math lives in one shared tile function
(:func:`compare_tile`) executed identically by the Pallas body and the
``lax.map`` twin (:func:`digest_compare_tiled`), and re-derived
whole-array by the dense oracle
(``repro.kernels.ref.digest_compare_ref``) — integer-only compares, so
all three are *bit-exact* replicas (``tests/test_gossip.py`` sweeps
range counts, tile sizes, and empty/fully-stale replicas).

Verdict semantics per (pair, range) row:

  * ``DIFFER``   — any digest component disagrees (the stale-range
    mask: this range needs a repair merge);
  * ``A_BEHIND`` / ``B_BEHIND`` — which side is missing versions,
    ordered by (MAX, then SUM); a tie on both with differing CHK/CNT
    means the replicas *diverged* within the range and both flags are
    set (the repair merge is symmetric anyway — direction is
    telemetry).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

# Packed input layout: one row per (pair, range), columns below, padded
# to DIG_COLS so tiles stay lane-aligned.  Inert rows have VALID=0 and
# produce all-zero verdicts.
A_SUM, A_MAX, A_CHK, A_CNT = 0, 1, 2, 3
B_SUM, B_MAX, B_CHK, B_CNT = 4, 5, 6, 7
VALID = 8
DIG_COLS = 16

# Output layout (int32 0/1 flags).
DIFFER, A_BEHIND, B_BEHIND = 0, 1, 2
OUT_COLS = 4


def compare_tile(tile: jax.Array) -> jax.Array:
    """Verdicts for one ``(block, DIG_COLS)`` tile — the one shared
    implementation of the compare math (integer-only, so the Pallas
    kernel, the jnp twin, and the dense oracle agree bit-for-bit)."""
    d_sum = tile[:, A_SUM] - tile[:, B_SUM]
    d_max = tile[:, A_MAX] - tile[:, B_MAX]
    d_chk = tile[:, A_CHK] - tile[:, B_CHK]
    d_cnt = tile[:, A_CNT] - tile[:, B_CNT]
    valid = tile[:, VALID] > 0
    differ = valid & (
        (d_sum != 0) | (d_max != 0) | (d_chk != 0) | (d_cnt != 0)
    )
    # Direction by (MAX, then SUM); a full tie that still differs
    # (CHK/CNT disagree) is divergence — both sides need the merge.
    tie = (d_max == 0) & (d_sum == 0)
    a_behind = differ & ((d_max < 0) | ((d_max == 0) & (d_sum < 0)) | tie)
    b_behind = differ & ((d_max > 0) | ((d_max == 0) & (d_sum > 0)) | tie)
    zeros = jnp.zeros_like(differ)
    return jnp.stack(
        [differ, a_behind, b_behind, zeros], axis=1
    ).astype(jnp.int32)


def pack_digests(
    a: jax.Array,      # (M, 4) int32 — side-A digest components
    b: jax.Array,      # (M, 4) int32 — side-B digest components
    *,
    block: int,
) -> jax.Array:
    """Pack paired digest rows into the kernel's ``(M', DIG_COLS)``
    layout, padded to a ``block`` multiple with inert (VALID=0) rows."""
    m = a.shape[0]
    pad = (-m) % block
    packed = jnp.zeros((m + pad, DIG_COLS), jnp.int32)
    packed = packed.at[:m, A_SUM:A_CNT + 1].set(a.astype(jnp.int32))
    packed = packed.at[:m, B_SUM:B_CNT + 1].set(b.astype(jnp.int32))
    packed = packed.at[:m, VALID].set(1)
    return packed


def _digest_compare_kernel(in_ref, out_ref):
    out_ref[...] = compare_tile(in_ref[...])


def digest_compare_pallas(
    packed: jax.Array,  # (M', DIG_COLS) int32, M' a multiple of block
    *,
    block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tiled digest compare; returns the ``(M', OUT_COLS)`` verdicts."""
    m = packed.shape[0]
    block = min(block, m)
    assert m % block == 0, f"M={m} must be a multiple of block={block}"
    nb = m // block
    return pl.pallas_call(
        _digest_compare_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, DIG_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, OUT_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, OUT_COLS), jnp.int32),
        compiler_params=CompilerParams(
            # Row tiles are independent; let the compiler parallelize.
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(packed)


def digest_compare_tiled(
    packed: jax.Array,
    *,
    block: int = 128,
) -> jax.Array:
    """jnp twin of the Pallas kernel: same tile walk, ``lax.map`` grid.

    The CPU fast path (Pallas runs interpreted there) — O(block) rows
    live per step, and bit-exact with the kernel because every tile
    runs the identical :func:`compare_tile`."""
    m = packed.shape[0]
    block = min(block, m)
    assert m % block == 0, f"M={m} must be a multiple of block={block}"
    nb = m // block
    tiles = packed.reshape(nb, block, DIG_COLS)
    out = jax.lax.map(compare_tile, tiles)
    return out.reshape(m, OUT_COLS)
