"""Tiled op-ingestion: the batched X-STCC hot path in O(B·tile) memory.

``repro.core.xstcc.apply_op_batch`` needs, per op ``i`` of a ``(B,)``
batch, three prefix reductions over the ops ``j < i`` (and the pending
ring): the per-resource write count ``occ``, the replica-visible version
``raw``, and the per-(client, resource) session-floor max ``floor``.
The dense formulation (``repro.kernels.ref.op_ingest_ref``) materializes
five ``(B, B)`` relation masks plus a ``(B, Q)`` pending mask — O(B²)
HBM that caps the batch size the engine can sustain.

This module computes the same reductions by streaming ``(Bi, Bj)``
blocks of the batch:

  * :func:`op_ingest_pallas` — the Pallas TPU kernel.  A sequential
    1-D grid ("arbitrary" semantics) walks the lower-triangular tile
    pairs ``(t, u <= t)``; each row tile accumulates its partial
    sums/maxima into its output block across the column tiles
    ``u < t``, then at the diagonal step ``u == t`` folds the
    intra-tile lower triangle, the pending ring, and the gathered
    state vectors, and publishes the tile's write versions and floor
    contributions into a persistent ``(B, 2)`` buffer that later row
    tiles read — per-step memory is O(tile² + B + Q), never O(B²).
  * :func:`op_ingest_tiled` — the same block walk as a ``lax.scan``
    over row tiles in plain jnp (one ``(tile, B)`` strip per step),
    the fast path on CPU where Pallas runs interpreted.

Visibility inside a tile is the closed-form cadence predicate (no
precomputed masks cross the API):

    visible(i, j) = is_write(j) ∧ same_resource(i, j) ∧
                    (coordinator(i) == coordinator(j)
                     ∨ op_index(i) >= apply_index(j))

with ``apply_index`` = 0 for merge-every-op levels, the stream
scheduler's emulated apply points for the op-index / timed-Δ cadences,
and the ``NEVER`` sentinel for plain scalar-loop semantics.  All three
implementations are integer-exact and must agree bit-for-bit with the
oracle (``tests/test_op_ingest.py`` sweeps them).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import NEVER, op_ingest_ref

Array = jax.Array

# op meta columns (B, OP_COLS) int32
CLIENT, REPLICA, RESOURCE, IS_WRITE, GLOBAL0, RAW0, FLOOR0 = 0, 1, 2, 3, 4, 5, 6
OPIDX, APPLYIDX = 7, 8
OP_COLS = 16
# pending meta columns (Q, PEND_COLS) int32
PVER, PRES, PLIVE, PAPPLY = 0, 1, 2, 3
PEND_COLS = 8
# output columns (B, OUT_COLS) int32
OCC, RAW, FLOOR = 0, 1, 2
OUT_COLS = 8
# persistent tile-exchange buffer columns (B, BUF_COLS) int32
VERW, CONTRIB = 0, 1
BUF_COLS = 8


class _Packed(NamedTuple):
    meta: Array       # (Bp, OP_COLS) int32
    pend: Array       # (Qp, PEND_COLS) int32
    b: int            # true batch length (rows beyond it are inert pads)


def pack_ops(
    client: Array,
    replica: Array,
    resource: Array,
    is_write: Array,
    g0: Array,
    raw0: Array,
    floor0: Array,
    *,
    op_index: Array | None = None,
    apply_index: Array | None = None,
    pend_version: Array | None = None,
    pend_resource: Array | None = None,
    pend_live: Array | None = None,
    pend_apply: Array | None = None,
    block: int = 128,
) -> _Packed:
    """Pack the per-op vectors into the kernel's meta layout.

    Pads the batch to a ``block`` multiple with inert rows (reads on
    resource ``-1`` — they match nothing and sort after every real op,
    so they contribute to no reduction) and the pending ring to a lane
    multiple with dead slots.  ``apply_index=None`` (scalar semantics)
    packs the ``NEVER`` sentinel so the cadence predicate is vacuously
    false.
    """
    b = client.shape[0]
    pad = (-b) % block

    def pcol(x, fill=0):
        x = jnp.asarray(x, jnp.int32)
        return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

    bp = b + pad
    meta = jnp.zeros((bp, OP_COLS), jnp.int32)
    meta = meta.at[:, CLIENT].set(pcol(client))
    meta = meta.at[:, REPLICA].set(pcol(replica, -1))
    meta = meta.at[:, RESOURCE].set(pcol(resource, -1))
    meta = meta.at[:, IS_WRITE].set(
        pcol(jnp.asarray(is_write).astype(jnp.int32))
    )
    meta = meta.at[:, GLOBAL0].set(pcol(g0))
    meta = meta.at[:, RAW0].set(pcol(raw0))
    meta = meta.at[:, FLOOR0].set(pcol(floor0))
    meta = meta.at[:, OPIDX].set(
        pcol(jnp.zeros((b,), jnp.int32) if op_index is None else op_index)
    )
    meta = meta.at[:, APPLYIDX].set(
        pcol(
            jnp.full((b,), NEVER, jnp.int32)
            if apply_index is None else apply_index,
            NEVER,
        )
    )

    q = 0 if pend_version is None else pend_version.shape[0]
    qp = max(8, q + (-q) % 8)
    pend = jnp.zeros((qp, PEND_COLS), jnp.int32)
    pend = pend.at[:, PRES].set(-1)
    if q:
        pend = pend.at[:q, PVER].set(jnp.asarray(pend_version, jnp.int32))
        pend = pend.at[:q, PRES].set(jnp.asarray(pend_resource, jnp.int32))
        pend = pend.at[:q, PLIVE].set(
            jnp.asarray(pend_live).astype(jnp.int32)
        )
        pend = pend.at[:q, PAPPLY].set(
            jnp.full((q,), NEVER, jnp.int32)
            if pend_apply is None
            else jnp.asarray(pend_apply, jnp.int32)
        )
    return _Packed(meta=meta, pend=pend, b=b)


# -- shared tile math (identical jnp ops in the Pallas body and the scan) ----


def _pair_parts(rows: Array, cols: Array, prior: Array):
    """Relation masks for one (rows × cols) block.

    ``prior`` is the order mask (row's global index > col's).  Returns
    ``(prior_w, vis, floor_mask)``: prior same-resource writes, the
    cadence-visible subset, and the session-floor (same client &
    resource) pairs.
    """
    same_r = rows[:, RESOURCE][:, None] == cols[:, RESOURCE][None, :]
    prior_w = prior & same_r & (cols[:, IS_WRITE][None, :] > 0)
    vis = prior_w & (
        (rows[:, REPLICA][:, None] == cols[:, REPLICA][None, :])
        | (rows[:, OPIDX][:, None] >= cols[:, APPLYIDX][None, :])
    )
    floor_mask = prior & same_r & (
        rows[:, CLIENT][:, None] == cols[:, CLIENT][None, :]
    )
    return prior_w, vis, floor_mask


def _cross_parts(rows: Array, cols: Array, prior: Array, buf: Array):
    """Partial reductions of one already-finalized column block."""
    prior_w, vis, floor_mask = _pair_parts(rows, cols, prior)
    occ_part = jnp.sum(prior_w, axis=1, dtype=jnp.int32)
    vis_part = jnp.max(jnp.where(vis, buf[:, VERW][None, :], 0), axis=1)
    floor_part = jnp.max(
        jnp.where(floor_mask, buf[:, CONTRIB][None, :], 0), axis=1
    )
    return occ_part, vis_part, floor_part


def _finalize_tile(
    rows: Array, occ_acc: Array, vis_acc: Array, floor_acc: Array,
    pend: Array,
):
    """Diagonal step: intra-tile triangle + pending ring + state joins.

    ``occ/vis/floor_acc`` are the accumulated cross-tile partials.
    Returns the tile's final ``(occ, raw, floor)`` plus its
    ``(verw, contrib)`` buffer row for later tiles.
    """
    t = rows.shape[0]
    iota = functools.partial(jax.lax.broadcasted_iota, jnp.int32, (t, t))
    prior = iota(0) > iota(1)
    prior_w, vis, floor_mask = _pair_parts(rows, rows, prior)

    occ = occ_acc + jnp.sum(prior_w, axis=1, dtype=jnp.int32)
    is_w = rows[:, IS_WRITE] > 0
    ver_w = rows[:, GLOBAL0] + occ + 1
    verw = jnp.where(is_w, ver_w, 0)

    vis_max = jnp.maximum(
        vis_acc, jnp.max(jnp.where(vis, verw[None, :], 0), axis=1)
    )
    pvis = (
        (pend[:, PLIVE][None, :] > 0)
        & (rows[:, RESOURCE][:, None] == pend[:, PRES][None, :])
        & (rows[:, OPIDX][:, None] >= pend[:, PAPPLY][None, :])
    )
    pend_max = jnp.max(jnp.where(pvis, pend[:, PVER][None, :], 0), axis=1)
    raw = jnp.maximum(jnp.maximum(rows[:, RAW0], vis_max), pend_max)

    contrib = jnp.where(is_w, ver_w, raw)
    floor = jnp.maximum(
        jnp.maximum(rows[:, FLOOR0], floor_acc),
        jnp.max(jnp.where(floor_mask, contrib[None, :], 0), axis=1),
    )
    return occ, raw, floor, verw, contrib


# -- Pallas kernel -----------------------------------------------------------


def _tri_coords(i):
    """(t, u) of the i-th step of the lower-triangular (t, u <= t) walk.

    ``t = floor((sqrt(8i+1)-1)/2)`` in f32, then corrected by ±1
    against the exact integer triangular numbers — f32 rounding error
    is far below 1 for any realistic tile count, and the correction
    makes the mapping exact regardless.
    """
    i = i.astype(jnp.int32)
    f = (jnp.sqrt(8.0 * i.astype(jnp.float32) + 1.0) - 1.0) * 0.5
    t = f.astype(jnp.int32)
    t = jnp.where(t * (t + 1) // 2 > i, t - 1, t)
    t = jnp.where((t + 1) * (t + 2) // 2 <= i, t + 1, t)
    u = i - t * (t + 1) // 2
    return t, u


def _op_ingest_kernel(rows_ref, cols_ref, pend_ref, out_ref, buf_ref,
                      *, block: int):
    t, u = _tri_coords(pl.program_id(0))

    @pl.when(u == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, jnp.int32)

    @pl.when(u < t)
    def _cross():
        rows = rows_ref[...]
        cols = cols_ref[...]
        buf = buf_ref[pl.ds(u * block, block), :]
        # Every pair of a strictly-cross tile is ordered (row indices
        # all exceed column indices), so the order mask is just True.
        occ_p, vis_p, floor_p = _cross_parts(rows, cols, True, buf)
        out = out_ref[...]
        out = out.at[:, OCC].set(out[:, OCC] + occ_p)
        out = out.at[:, RAW].set(jnp.maximum(out[:, RAW], vis_p))
        out = out.at[:, FLOOR].set(jnp.maximum(out[:, FLOOR], floor_p))
        out_ref[...] = out

    @pl.when(u == t)
    def _diag():
        rows = rows_ref[...]
        acc = out_ref[...]
        occ, raw, floor, verw, contrib = _finalize_tile(
            rows, acc[:, OCC], acc[:, RAW], acc[:, FLOOR], pend_ref[...]
        )
        out = jnp.zeros(out_ref.shape, jnp.int32)
        out = out.at[:, OCC].set(occ)
        out = out.at[:, RAW].set(raw)
        out = out.at[:, FLOOR].set(floor)
        out_ref[...] = out
        buf = jnp.zeros((block, BUF_COLS), jnp.int32)
        buf = buf.at[:, VERW].set(verw)
        buf = buf.at[:, CONTRIB].set(contrib)
        buf_ref[pl.ds(t * block, block), :] = buf


def op_ingest_pallas(
    packed: _Packed, *, block: int = 128, interpret: bool = False
) -> tuple[Array, Array, Array]:
    """Tiled ingest via ``pallas_call``.  Returns ``(occ, raw, floor)``."""
    meta, pend, b = packed
    bp = meta.shape[0]
    qp = pend.shape[0]
    assert bp % block == 0, f"padded B={bp} must tile into block={block}"
    nb = bp // block

    row_of = lambda i: (_tri_coords(i)[0], 0)                # noqa: E731
    col_of = lambda i: (_tri_coords(i)[1], 0)                # noqa: E731
    out, _ = pl.pallas_call(
        functools.partial(_op_ingest_kernel, block=block),
        # One step per ordered tile pair (t, u <= t) — the grid walks
        # only the lower triangle, nothing is fetched for u > t.
        grid=(nb * (nb + 1) // 2,),
        in_specs=[
            pl.BlockSpec((block, OP_COLS), row_of),
            pl.BlockSpec((block, OP_COLS), col_of),
            pl.BlockSpec((qp, PEND_COLS), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, OUT_COLS), row_of),
            pl.BlockSpec((bp, BUF_COLS), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, OUT_COLS), jnp.int32),
            jax.ShapeDtypeStruct((bp, BUF_COLS), jnp.int32),
        ],
        compiler_params=CompilerParams(
            # Row tiles accumulate across column steps and read buffer
            # rows published by earlier diagonal steps: strict order.
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(meta, meta, pend)
    return out[:b, OCC], out[:b, RAW], out[:b, FLOOR]


# -- jnp tiled twin (the CPU fast path) --------------------------------------


def op_ingest_tiled(packed: _Packed, *, block: int = 256):
    """The kernel's block walk as a ``lax.scan`` over tile *pairs*.

    Walks the same lower-triangular ``(t, u <= t)`` tile-pair sequence
    as the Pallas grid — a ``lax.switch`` picks the cross-tile partial
    step or the diagonal finalize step — so only the ~B²/2 ordered
    pairs are ever touched and every step works on ``(block, block)``
    tiles: peak memory O(B·block) for the carried accumulators, never
    O(B²).
    """
    meta, pend, b = packed
    bp = meta.shape[0]
    nb = bp // block

    # Static triangular schedule: for each row tile, its cross partials
    # in column order, then its diagonal finalize (which publishes the
    # tile's verw/contrib for later row tiles — same order the Pallas
    # grid executes).
    ts, us = [], []
    for t in range(nb):
        for u in range(t + 1):
            ts.append(t)
            us.append(u)
    schedule = (
        jnp.asarray(np.asarray(ts, np.int32)),
        jnp.asarray(np.asarray(us, np.int32)),
    )

    def cross(carry, t, u):
        buf, acc, out = carry
        rows = jax.lax.dynamic_slice(meta, (t * block, 0), (block, OP_COLS))
        cols = jax.lax.dynamic_slice(meta, (u * block, 0), (block, OP_COLS))
        bufu = jax.lax.dynamic_slice(buf, (u * block, 0), (block, BUF_COLS))
        occ_p, vis_p, floor_p = _cross_parts(rows, cols, True, bufu)
        acct = jax.lax.dynamic_slice(acc, (t * block, 0), (block, 4))
        acct = acct.at[:, OCC].add(occ_p)
        acct = acct.at[:, RAW].max(vis_p)
        acct = acct.at[:, FLOOR].max(floor_p)
        acc = jax.lax.dynamic_update_slice(acc, acct, (t * block, 0))
        return buf, acc, out

    def diag(carry, t, u):
        del u
        buf, acc, out = carry
        rows = jax.lax.dynamic_slice(meta, (t * block, 0), (block, OP_COLS))
        acct = jax.lax.dynamic_slice(acc, (t * block, 0), (block, 4))
        occ, raw, floor, verw, contrib = _finalize_tile(
            rows, acct[:, OCC], acct[:, RAW], acct[:, FLOOR], pend
        )
        outt = jnp.stack([occ, raw, floor, jnp.zeros_like(occ)], axis=1)
        out = jax.lax.dynamic_update_slice(out, outt, (t * block, 0))
        buft = jnp.zeros((block, BUF_COLS), jnp.int32)
        buft = buft.at[:, VERW].set(verw)
        buft = buft.at[:, CONTRIB].set(contrib)
        buf = jax.lax.dynamic_update_slice(buf, buft, (t * block, 0))
        return buf, acc, out

    def step(carry, tu):
        t, u = tu
        carry = jax.lax.cond(
            u == t,
            lambda c: diag(c, t, u),
            lambda c: cross(c, t, u),
            carry,
        )
        return carry, None

    zeros = lambda w: jnp.zeros((bp, w), jnp.int32)          # noqa: E731
    (_, _, out), _ = jax.lax.scan(
        step, (zeros(BUF_COLS), zeros(4), zeros(4)), schedule
    )
    return out[:b, OCC], out[:b, RAW], out[:b, FLOOR]


# -- fused closed-form path (the CPU hot path) -------------------------------


def _seg_prefix_max(seg: Array, val: Array, n_segs: int) -> Array:
    """Exclusive per-segment prefix max of ``val`` in stream order.

    ``out[i] = max(val[j] for j < i with seg[j] == seg[i])`` (identity
    0) in O(B log B): sort the packed key ``seg * B + i`` — the sorted
    key *is* the permutation (``key % B``) and the segment run map
    (``key // B``), so no argsort is ever materialized — then run a
    segmented inclusive max scan over the runs and shift it exclusive.
    Caller must guarantee ``n_segs * B < 2**31`` (the packed key stays
    int32); :func:`repro.kernels.ops.op_ingest` checks this before
    selecting the fused path.
    """
    b = seg.shape[0]
    assert n_segs * b < 2 ** 31, "packed segment key overflows int32"
    key = seg * jnp.int32(b) + jnp.arange(b, dtype=jnp.int32)
    skey = jax.lax.sort(key)
    perm = skey % jnp.int32(b)
    sseg = skey // jnp.int32(b)
    start = jnp.concatenate(
        [jnp.ones((1,), bool), sseg[1:] != sseg[:-1]]
    )
    sval = val[perm]

    def combine(a, c):
        va, fa = a
        vc, fc = c
        return jnp.where(fc, vc, jnp.maximum(va, vc)), fa | fc

    incl, _ = jax.lax.associative_scan(combine, (sval, start))
    exc = jnp.where(
        start, 0, jnp.concatenate([jnp.zeros((1,), val.dtype), incl[:-1]])
    )
    return jnp.zeros((b,), val.dtype).at[perm].set(exc)


def op_ingest_fused(
    client: Array,
    replica: Array,
    resource: Array,
    is_write: Array,
    g0: Array,
    raw0: Array,
    floor0: Array,
    *,
    n_clients: int,
    n_replicas: int,
    n_resources: int,
    op_index: Array | None = None,
    apply_index: Array | None = None,
    pend_version: Array | None = None,
    pend_resource: Array | None = None,
    pend_live: Array | None = None,
    pend_apply: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Closed-form ingest: O(B·R + B log B), no O(B²) pair sweep.

    Bit-identical to :func:`repro.kernels.ref.op_ingest_ref` — the three
    reductions are per-segment prefix counts/maxima, so they collapse to

      * ``occ``   — an exclusive per-resource running count of writes
        (one ``(B, R)`` cumsum);
      * the coordinator-visible and session-floor maxima — exclusive
        per-(replica, resource) / per-(client, resource) prefix maxima
        via :func:`_seg_prefix_max`;
      * the cadence-visible and pending-ring maxima — an activation
        *timeline*: batch op indices are affine, so write ``j`` (pending
        slot ``q``) becomes visible to every op from batch-local index
        ``max(j+1, apply_index[j] - op_index[0])`` (``pend_apply[q] -
        op_index[0]``) on; scattering versions at their activation rows
        of a ``(B+1, R)`` grid and running a cumulative max down the op
        axis serves every op its visible per-resource max.

    Preconditions (checked by the dispatch in ``repro.kernels.ops``):
    ``op_index`` affine (``op_index[i] == op_index[0] + i`` — every
    store-layer batch is), ids in range, and the packed segment keys
    fit int32.  Unlike the tiled/Pallas paths this needs the static
    state sizes, but touches no padded pair blocks at all.
    """
    c = jnp.asarray(client, jnp.int32)
    p = jnp.asarray(replica, jnp.int32)
    r = jnp.asarray(resource, jnp.int32)
    is_w = jnp.asarray(is_write, bool)
    g0 = jnp.asarray(g0, jnp.int32)
    raw0 = jnp.asarray(raw0, jnp.int32)
    floor0 = jnp.asarray(floor0, jnp.int32)
    b = c.shape[0]
    R = n_resources

    # occ: exclusive per-resource prefix write count.
    onehot = (
        (r[:, None] == jnp.arange(R, dtype=jnp.int32)[None, :])
        & is_w[:, None]
    ).astype(jnp.int32)
    exc_cnt = jnp.cumsum(onehot, axis=0) - onehot
    occ = jnp.take_along_axis(exc_cnt, r[:, None], axis=1)[:, 0]

    ver_w = g0 + occ + 1
    verw = jnp.where(is_w, ver_w, 0)

    # Coordinator visibility: per-(replica, resource) prefix max.
    coord_max = _seg_prefix_max(p * jnp.int32(R) + r, verw, n_replicas * R)

    raw = jnp.maximum(raw0, coord_max)
    if apply_index is not None or pend_apply is not None:
        step0 = jnp.asarray(op_index, jnp.int32)[0]
        rows = jnp.arange(1, b + 1, dtype=jnp.int32)
        timeline = jnp.zeros((b + 1, R), jnp.int32)
        if apply_index is not None:
            act = jnp.clip(
                jnp.maximum(rows, jnp.asarray(apply_index, jnp.int32) - step0),
                0, b,
            )
            timeline = timeline.at[act, r].max(verw)
        if pend_apply is not None:
            pact = jnp.clip(
                jnp.asarray(pend_apply, jnp.int32) - step0, 0, b
            )
            res_safe = jnp.where(
                jnp.asarray(pend_live, bool),
                jnp.asarray(pend_resource, jnp.int32),
                R,
            )
            timeline = timeline.at[pact, res_safe].max(
                jnp.asarray(pend_version, jnp.int32), mode="drop"
            )
        seen = jax.lax.cummax(timeline, axis=0)
        cad = seen[jnp.arange(b, dtype=jnp.int32), r]
        raw = jnp.maximum(raw, cad)

    # Session floor: per-(client, resource) prefix max of contributions.
    contrib = jnp.where(is_w, ver_w, raw)
    floor = jnp.maximum(
        floor0,
        _seg_prefix_max(c * jnp.int32(R) + r, contrib, n_clients * R),
    )
    return occ, raw, floor


__all__ = [
    "pack_ops",
    "op_ingest_pallas",
    "op_ingest_tiled",
    "op_ingest_fused",
    "op_ingest_ref",
    "NEVER",
]
