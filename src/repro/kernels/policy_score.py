"""Pallas TPU kernel for the batched (sessions × levels) SLA scorer.

The adaptive control plane (``repro.policy``) re-scores every session
against every candidate consistency level each merge epoch: blend the
analytic per-level $ cost with windowed staleness telemetry, check the
four SLA bounds, and emit a utility whose argmax is the cheapest
feasible level.  At fleet scale (10^5-10^6 sessions × 6 levels, every
epoch) this is a pure VPU workload: all operands are dense, the math is
elementwise over the (S, L) grid with rank-1 broadcasts from the packed
session-parameter rows and level-table columns.

The kernel tiles the session axis; each grid step loads one
``(block_s, SP_COLS)`` slab of session params plus the whole
``(LVL_COLS, L)`` level table (tiny, replicated to every step) and the
matching ``(block_s, L)`` telemetry tiles, then writes the scored
``(block_s, L)`` utility/feasibility tiles.  No cross-tile state, so
grid steps are independent.

Semantics are defined by ``repro.kernels.ref.policy_score_ref`` — the
acceptance bar is *bit-exact* agreement (identical op order and
dtypes), checked in ``tests/test_policy.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import (
    INFEASIBLE_PENALTY,
    LVL_COLS,
    LVL_READ_COST,
    LVL_READ_LAT,
    LVL_REPAIR_COST,
    LVL_STALE_AGE,
    LVL_WRITE_COST,
    SP_COLS,
    SP_MAX_AGE,
    SP_MAX_LAT,
    SP_MAX_STALE,
    SP_MAX_VIOL,
    SP_READ_FRAC,
    SP_VALID,
    STRUCTURAL_WEIGHT,
)


def _policy_score_kernel(sess_ref, lvl_ref, stale_ref, viol_ref, count_ref,
                         util_ref, feas_ref):
    sess = sess_ref[...]          # (bs, SP_COLS)
    table = lvl_ref[...]          # (LVL_COLS, L)
    stale = stale_ref[...]        # (bs, L)
    viol = viol_ref[...]
    count = count_ref[...]

    col = lambda i: sess[:, i:i + 1]          # noqa: E731
    rf = col(SP_READ_FRAC)
    max_stale = col(SP_MAX_STALE)
    max_viol = col(SP_MAX_VIOL)
    max_lat = col(SP_MAX_LAT)
    max_age = col(SP_MAX_AGE)
    valid = col(SP_VALID) > 0.0

    read_cost = table[LVL_READ_COST][None, :]
    write_cost = table[LVL_WRITE_COST][None, :]
    repair = table[LVL_REPAIR_COST][None, :]
    lat = table[LVL_READ_LAT][None, :]
    age = table[LVL_STALE_AGE][None, :]

    has = count > 0.0
    s_e = jnp.where(has, stale, 0.0)
    v_e = jnp.where(has, viol, 0.0)
    cost = rf * (read_cost + s_e * repair) + (1.0 - rf) * write_cost
    eps = jnp.float32(1.0e-6)
    structural = jnp.float32(STRUCTURAL_WEIGHT)
    excess = (
        jnp.maximum(s_e - max_stale, 0.0) / jnp.maximum(max_stale, eps)
        + jnp.maximum(v_e - max_viol, 0.0) / jnp.maximum(max_viol, eps)
        + structural * (lat > max_lat).astype(jnp.float32)
        + structural * (age > max_age).astype(jnp.float32)
    )
    feas = (excess == 0.0) & valid
    util_ref[...] = jnp.where(
        valid, -cost - jnp.float32(INFEASIBLE_PENALTY) * excess, 0.0
    )
    feas_ref[...] = feas.astype(jnp.int32)


def policy_score(
    sess: jax.Array,    # (S, SP_COLS) f32
    table: jax.Array,   # (LVL_COLS, L) f32
    stale: jax.Array,   # (S, L) f32
    viol: jax.Array,    # (S, L) f32
    count: jax.Array,   # (S, L) f32
    *,
    block_s: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Tiled fleet scoring.  Returns ``(utility, feasible)``:
    ``utility`` (S, L) float32, ``feasible`` (S, L) int32.

    ``S`` must be a multiple of ``block_s`` (pad with SP_VALID=0 rows —
    the jit'd wrapper ``repro.kernels.ops.policy_score`` does this).
    """
    s, l = stale.shape
    block_s = min(block_s, s)
    assert s % block_s == 0, f"S={s} must be a multiple of block_s={block_s}"
    nb = s // block_s

    return pl.pallas_call(
        _policy_score_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_s, SP_COLS), lambda i: (i, 0)),
            pl.BlockSpec((LVL_COLS, l), lambda i: (0, 0)),
            pl.BlockSpec((block_s, l), lambda i: (i, 0)),
            pl.BlockSpec((block_s, l), lambda i: (i, 0)),
            pl.BlockSpec((block_s, l), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, l), lambda i: (i, 0)),
            pl.BlockSpec((block_s, l), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, l), jnp.float32),
            jax.ShapeDtypeStruct((s, l), jnp.int32),
        ],
        compiler_params=CompilerParams(
            # Tiles are independent; let the compiler parallelize.
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        jnp.asarray(sess, jnp.float32),
        jnp.asarray(table, jnp.float32),
        jnp.asarray(stale, jnp.float32),
        jnp.asarray(viol, jnp.float32),
        jnp.asarray(count, jnp.float32),
    )
