"""Pallas TPU kernels (+ jnp oracles) for the framework's hot-spots:

  flash_attention — prefill/train attention (MXU-tiled online softmax).
  vclock_audit    — DUOT pairwise causality audit (paper §3.3).
  session_floor   — batched X-STCC session-floor admission check (the
                    serving-path per-op hot loop).
  op_ingest       — tiled batched op-ingestion prefixes (versions /
                    visibility / floors) in O(B·tile) memory: the
                    engine hot path behind ``xstcc.apply_op_batch``.
  policy_score    — (sessions × levels) SLA feasibility/utility scorer
                    for the adaptive consistency control plane.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
