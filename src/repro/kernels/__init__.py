"""Pallas TPU kernels (+ jnp oracles) for the framework's hot-spots:

  flash_attention — prefill/train attention (MXU-tiled online softmax).
  vclock_audit    — DUOT pairwise causality audit (paper §3.3).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
