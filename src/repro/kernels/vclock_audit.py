"""Pallas TPU kernel for the DUOT causality audit (paper §3.3-3.4).

The audit is O(M^2 * N) vector-clock comparisons over an M-entry op log
with N clients — the server-side hot-spot of X-STCC (every merge audits
the log; Cassandra-scale logs run to millions of ops).  The kernel tiles
the (M x M) pair space into (block x block) VMEM tiles; the N clock
components are reduced with an unrolled 2-D loop (max/min of component
differences), keeping every intermediate a (block x block) tile — TPU
vector-unit friendly, no 3-D temporaries.

happens-before(a, b)  <=>  max_n(a_n - b_n) <= 0  and  min_n(a_n - b_n) < 0

Output codes match ``repro.kernels.ref.vclock_audit_ref``:
``phase | violation << 8 | timed << 9``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

# meta columns
CLIENT, KIND, RESOURCE, VERSION, SEQ, VALID = 0, 1, 2, 3, 4, 5
META_COLS = 8


def _audit_kernel(vci_ref, vcj_ref, mi_ref, mj_ref, out_ref,
                  *, n_clients: int, delta: int):
    vci = vci_ref[...]          # (bm, N)
    vcj = vcj_ref[...]          # (bm, N)
    mi = mi_ref[...]            # (bm, META_COLS)
    mj = mj_ref[...]            # (bm, META_COLS)
    bm = vci.shape[0]

    big = jnp.int32(-(2 ** 30))
    maxd = jnp.full((bm, bm), big, jnp.int32)
    mind = jnp.full((bm, bm), -big, jnp.int32)
    for n in range(n_clients):
        diff = vci[:, n][:, None] - vcj[:, n][None, :]
        maxd = jnp.maximum(maxd, diff)
        mind = jnp.minimum(mind, diff)
    hb = jnp.logical_and(maxd <= 0, mind < 0)

    def col(m, c):
        return m[:, c]

    valid = jnp.logical_and(
        col(mi, VALID)[:, None] > 0, col(mj, VALID)[None, :] > 0)
    same_res = col(mi, RESOURCE)[:, None] == col(mj, RESOURCE)[None, :]
    ordered = col(mi, SEQ)[:, None] < col(mj, SEQ)[None, :]
    same_client = col(mi, CLIENT)[:, None] == col(mj, CLIENT)[None, :]
    ki = col(mi, KIND)[:, None]
    kj = col(mj, KIND)[None, :]
    vi = col(mi, VERSION)[:, None]
    vj = col(mj, VERSION)[None, :]

    base = valid & same_res & ordered
    sc = base & same_client & hb

    phase = jnp.zeros((bm, bm), jnp.int32)
    phase = jnp.where(sc & (ki == 0) & (kj == 0), 1, phase)
    phase = jnp.where(sc & (ki == 1) & (kj == 1), 2, phase)
    phase = jnp.where(sc & (ki == 1) & (kj == 0), 3, phase)
    phase = jnp.where(sc & (ki == 0) & (kj == 1), 4, phase)
    phase = jnp.where(base & ~same_client & hb, 5, phase)
    phase = jnp.where(base & ~hb, 6, phase)

    viol = jnp.zeros((bm, bm), bool)
    viol |= (phase == 1) & (vj < vi)
    viol |= (phase == 2) & (vj <= vi)
    viol |= (phase == 3) & (vj < vi)
    viol |= (phase == 4) & (vj <= vi)
    viol |= (phase == 5) & (ki == 1) & (kj == 0) & (vj < vi)

    gap = col(mj, SEQ)[None, :] - col(mi, SEQ)[:, None]
    timed = base & (ki == 1) & (kj == 0) & (vj < vi) & (gap > delta)
    if delta <= 0:
        timed = jnp.zeros_like(timed)

    out_ref[...] = (
        phase
        | (viol.astype(jnp.int32) << 8)
        | (timed.astype(jnp.int32) << 9)
    )


def vclock_audit(
    vc: jax.Array,       # (M, N) int32
    client: jax.Array,   # (M,) int32
    kind: jax.Array,
    resource: jax.Array,
    version: jax.Array,
    seq: jax.Array,
    valid: jax.Array,    # (M,) bool
    *,
    delta: int = 0,
    block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tiled pairwise audit.  Returns (M, M) int32 codes."""
    m, n = vc.shape
    block = min(block, m)
    assert m % block == 0, f"M={m} must divide block={block}"
    meta = jnp.stack(
        [
            client.astype(jnp.int32),
            kind.astype(jnp.int32),
            resource.astype(jnp.int32),
            version.astype(jnp.int32),
            seq.astype(jnp.int32),
            valid.astype(jnp.int32),
            jnp.zeros((m,), jnp.int32),
            jnp.zeros((m,), jnp.int32),
        ],
        axis=1,
    )  # (M, META_COLS)

    kernel = functools.partial(_audit_kernel, n_clients=n, delta=delta)
    nb = m // block
    return pl.pallas_call(
        kernel,
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, n), lambda i, j: (i, 0)),
            pl.BlockSpec((block, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block, META_COLS), lambda i, j: (i, 0)),
            pl.BlockSpec((block, META_COLS), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(vc, vc, meta, meta)
