"""Pallas TPU kernel for the (resources × candidate-plans) placement scorer.

The replica-placement planner (``repro.geo.placement``) scores every
resource's regional demand vector against every candidate
(replication-factor × region-assignment) plan: an analytic eq. 5-8
bill blended over the (R, K, G) grid plus the SLA's structural latency
check.  At fleet scale (10^5-10^6 resources × hundreds of candidate
plans, re-planned as demand shifts) this is the same shape of VPU
workload as ``kernels/policy_score``: dense elementwise math over an
(R, K) grid with rank-1 broadcasts from per-candidate tables, reduced
over a tiny static region axis.

The kernel tiles the resource axis; each grid step loads one
``(block_r, G)`` slab of read/write demand plus the whole per-candidate
``(K, G)`` price/latency tables and the ``(2, K)`` candidate metadata
(storage cost, validity) — small, replicated to every step — and writes
the scored ``(block_r, K)`` utility/feasibility tiles.  The region
reduction is an unrolled fixed-order loop (``G`` is static and tiny),
which is what makes the kernel, the tiled jnp twin
(:func:`placement_score_tiled`), and the dense oracle
(``repro.kernels.ref.placement_score_ref``) *bit-exact* replicas of
each other — the acceptance bar checked in ``tests/test_geo.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import INFEASIBLE_PENALTY, STRUCTURAL_WEIGHT


def _placement_score_kernel(
    reads_ref, writes_ref, rprice_ref, wprice_ref, rtt_ref, meta_ref,
    util_ref, feas_ref, *, max_latency_ms: float,
):
    reads = reads_ref[...]          # (br, G)
    writes = writes_ref[...]        # (br, G)
    rprice = rprice_ref[...]        # (K, G)
    wprice = wprice_ref[...]        # (K, G)
    rtt = rtt_ref[...]              # (K, G)
    meta = meta_ref[...]            # (2, K)

    br, g = reads.shape
    k = rprice.shape[0]
    store = meta[0][None, :]
    valid = meta[1][None, :] > 0.0
    max_lat = jnp.float32(max_latency_ms)
    structural = jnp.float32(STRUCTURAL_WEIGHT)

    cost = jnp.broadcast_to(store, (br, k))
    excess = jnp.zeros((br, k), jnp.float32)
    for gi in range(g):             # static, fixed order — bit-exact twin
        cost = cost + reads[:, gi:gi + 1] * rprice[None, :, gi]
        cost = cost + writes[:, gi:gi + 1] * wprice[None, :, gi]
        demand = (reads[:, gi:gi + 1] + writes[:, gi:gi + 1]) > 0.0
        late = rtt[None, :, gi] > max_lat
        excess = excess + structural * jnp.logical_and(
            demand, late
        ).astype(jnp.float32)
    excess = excess + structural * jnp.logical_not(valid).astype(jnp.float32)
    feas = excess == 0.0
    util_ref[...] = -cost - jnp.float32(INFEASIBLE_PENALTY) * excess
    feas_ref[...] = feas.astype(jnp.int32)


def placement_score(
    reads: jax.Array,        # (R, G) f32
    writes: jax.Array,       # (R, G) f32
    read_price: jax.Array,   # (K, G) f32
    write_price: jax.Array,  # (K, G) f32
    read_rtt: jax.Array,     # (K, G) f32
    cand_meta: jax.Array,    # (2, K) f32
    *,
    max_latency_ms: float,
    block_r: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Tiled placement scoring.  Returns ``(utility, feasible)``:
    ``utility`` (R, K) float32, ``feasible`` (R, K) int32.

    ``R`` must be a multiple of ``block_r`` (pad with zero-demand rows —
    the jit'd wrapper ``repro.kernels.ops.placement_score`` does this).
    """
    r, g = reads.shape
    k = read_price.shape[0]
    block_r = min(block_r, r)
    assert r % block_r == 0, f"R={r} must be a multiple of block_r={block_r}"
    nb = r // block_r

    kernel = functools.partial(
        _placement_score_kernel, max_latency_ms=float(max_latency_ms)
    )
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_r, g), lambda i: (i, 0)),
            pl.BlockSpec((block_r, g), lambda i: (i, 0)),
            pl.BlockSpec((k, g), lambda i: (0, 0)),
            pl.BlockSpec((k, g), lambda i: (0, 0)),
            pl.BlockSpec((k, g), lambda i: (0, 0)),
            pl.BlockSpec((2, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            # Tiles are independent; let the compiler parallelize.
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        jnp.asarray(reads, jnp.float32),
        jnp.asarray(writes, jnp.float32),
        jnp.asarray(read_price, jnp.float32),
        jnp.asarray(write_price, jnp.float32),
        jnp.asarray(read_rtt, jnp.float32),
        jnp.asarray(cand_meta, jnp.float32),
    )


def placement_score_tiled(
    reads: jax.Array,
    writes: jax.Array,
    read_price: jax.Array,
    write_price: jax.Array,
    read_rtt: jax.Array,
    cand_meta: jax.Array,
    *,
    max_latency_ms: float,
    block_r: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """jnp twin of the Pallas kernel: same tile walk, ``lax.map`` grid.

    The CPU fast path (Pallas runs interpreted there) — O(block_r·K)
    live per step instead of the oracle's whole (R, K) intermediates,
    and bit-exact with both the kernel and the oracle because every
    tile runs the identical unrolled-region reduction.
    """
    from repro.kernels.ref import placement_score_ref

    r, g = reads.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, f"R={r} must be a multiple of block_r={block_r}"
    nb = r // block_r
    reads = jnp.asarray(reads, jnp.float32).reshape(nb, block_r, g)
    writes = jnp.asarray(writes, jnp.float32).reshape(nb, block_r, g)

    def tile(args):
        rd, wr = args
        return placement_score_ref(
            rd, wr, read_price, write_price, read_rtt, cand_meta,
            max_latency_ms=max_latency_ms,
        )

    util, feas = jax.lax.map(tile, (reads, writes))
    k = util.shape[-1]
    return util.reshape(r, k), feas.reshape(r, k)
